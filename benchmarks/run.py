"""Benchmark harness — one function per paper table/figure.

Prints ``name,value,derived`` CSV rows per benchmark.  Heavy artifacts
(trained experts, router, Q-tables) are produced once by
``python -m repro.core.experiment`` and re-read here; if absent, a reduced
experiment is run automatically.

  fig2      per-expert per-domain MLM accuracy (differential experts)
  fig3a     optimal-model selection accuracy vs baselines
  fig3b     domain -> expert allocation matrix fidelity
  fig3cd    per-domain aggregate accuracy, Tryage vs experts
  fig4      latent separation (silhouette scores)
  fig5      Pareto front (lambda sweep)
  router_eps  loss-prediction epsilon (paper: ~0.1)
  kernels   Pallas kernel microbenches (us/call, interpret mode)
  router_decision  router-decision throughput, fused kernel vs host path
  serving   engine throughput on batched requests
  scheduler continuous-batching vs FIFO-drain throughput + padded rows
  cascade   accuracy-vs-mean-size front: confidence-aware cascade
            routing vs single-shot routing (+ escalation telemetry)
  drift     online router adaptation under a mid-stream shift: the
            adapting engine must recover >= half of the routing-accuracy
            drop that leaves the frozen engine degraded (per-window
            timeline written to experiments/tryage/drift_timeline.csv)
  slo       routing availability + p99 SLO under bursty arrivals with
            one expert forced unhealthy mid-stream: the health-fallback
            engine must hold availability >= 0.99 while the health-
            unaware baseline degrades (per-window timeline written to
            experiments/tryage/slo_timeline.csv)
  mesh      sharded Execute-stage scaling across simulated mesh sizes
            1/2/4/8 (expert->slice placement + hot-expert replication):
            simulated overlapped flushed-tokens/s at mesh size 4 must
            be >= 3x size 1 and routing choices must not change
            (per-size rows in experiments/tryage/mesh_scaling.csv;
            run under XLA_FLAGS=--xla_force_host_platform_device_count=8)

Benchmarks whose gates depend on artifact quality (``cascade``,
``drift``) fail fast with a regeneration hint when the cached
experiments/tryage artifacts were generated below the fast config
(expert_steps < 60) — an ultra-reduced library gives near-random
accuracy and the gates are meaningless there.

Select a subset with ``--only kernels,scheduler``; ``--out bench.csv``
additionally writes the CSV to a file (CI uploads it as an artifact);
``--fast`` shrinks the fallback experiment when no artifacts are cached.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

# --fast, visible to benchmarks that scale their own work (bench
# functions only receive the artifact results); set once by main()
_FAST = {"fast": False}


def _results(fast: bool = False):
    from repro.core import experiment as ex
    try:
        return ex.load_results()
    except FileNotFoundError:
        print("# no cached artifacts; running reduced experiment", flush=True)
        if fast:
            xc = ex.ExperimentConfig(expert_steps=60, n_train_prompts=512,
                                     n_val_prompts=128, n_test_per_domain=24,
                                     router_epochs=3)
        else:
            xc = ex.ExperimentConfig(expert_steps=120, n_train_prompts=1024,
                                     n_val_prompts=192, n_test_per_domain=48,
                                     router_epochs=5)
        return ex.run_experiment(xc, verbose=False)


# quality floor for artifact-gated benchmarks: the fast experiment
# config.  Below this the experts are near-random, the router's Q-table
# supervision is noise, and the cascade/drift gates fail for reasons
# that have nothing to do with the code under test.
MIN_EXPERT_STEPS = 60


def _require_artifact_quality(res, bench_name):
    """Fail fast (with a regeneration hint) when the cached artifacts
    were generated below the fast config."""
    steps = (res or {}).get("config", {}).get("expert_steps", 0)
    if steps < MIN_EXPERT_STEPS:
        raise RuntimeError(
            f"{bench_name}: experiments/tryage artifacts were generated "
            f"with expert_steps={steps} < {MIN_EXPERT_STEPS} (below the "
            f"fast config) — the gate is meaningless at that quality. "
            f"Regenerate with: PYTHONPATH=src python -m "
            f"repro.core.experiment --fast  (~35 min on CPU)")


def bench_fig2(res):
    rows = []
    for d, accs in res["per_domain"].items():
        experts = {k: v for k, v in accs.items() if k != "tryage"}
        best = max(experts, key=experts.get)
        gen = experts.get("roberta-analog", 0.0)
        rows.append((f"fig2/{d}/best_expert", experts[best],
                     f"{best};generalist={gen:.3f}"))
    return rows


def bench_fig3a(res):
    rows = []
    for k, v in res["selection_accuracy"].items():
        rows.append((f"fig3a/selection_acc/{k.split()[0]}", v, ""))
    return rows


def bench_fig3b(res):
    from repro.data.corpus import DOMAINS
    alloc = np.array(res["allocation"])
    lib = [e["name"] for e in res["library"]]
    rows = []
    for di, d in enumerate(DOMAINS):
        mi = int(alloc[di].argmax())
        rows.append((f"fig3b/top_alloc/{d}", float(alloc[di, mi]), lib[mi]))
    return rows


def bench_fig3cd(res):
    rows = []
    for d, accs in res["per_domain"].items():
        gain = accs["tryage"] - accs.get("roberta-analog", 0.0)
        rows.append((f"fig3cd/tryage_minus_generalist/{d}", gain, ""))
    rows.append(("fig3cd/tryage_aggregate",
                 res["aggregate_accuracy"]["tryage"],
                 f"oracle={res['aggregate_accuracy']['oracle']:.3f}"))
    return rows


def bench_fig3a_mixed(res):
    """Mixed-domain prompts (the paper's motivating case) — produced by
    scripts/mixed_domain_eval.py from cached artifacts."""
    import json
    from repro.core import experiment as ex
    path = os.path.join(ex.ART_DIR, "mixed_results.json")
    with open(path) as f:
        mixed = json.load(f)
    rows = [(f"fig3a_mixed/selection_acc/{k.split()[0]}", v, "")
            for k, v in mixed["selection_accuracy"].items()]
    rows += [(f"fig3a_mixed/aggregate_acc/{k.split()[0]}", v, "")
             for k, v in mixed["aggregate_accuracy"].items()]
    return rows


def bench_fig4(res):
    return [(f"fig4/silhouette/{k}", v, "") for k, v in res["silhouette"].items()]


def bench_fig5(res):
    rows = []
    pareto = res["pareto"]["rows"]
    base = pareto[0]
    for r in pareto:
        if r["lam"] in (0.0, 1.0, 4.0, 16.0):
            rows.append((f"fig5/acc_at_lam_{r['lam']}", r["accuracy"],
                         f"size_frac={r['size_frac']:.3f}"))
    # headline: compute saved at <=5% accuracy drop
    ok = [r for r in pareto if r["accuracy"] >= base["accuracy"] - 0.05]
    best = min(ok, key=lambda r: r["mean_size"])
    rows.append(("fig5/compute_saved_at_5pct_drop",
                 1.0 - best["mean_size"] / base["mean_size"],
                 f"lam={best['lam']:.2f}"))
    return rows


def bench_router_eps(res):
    return [("router_eps/mean_abs_err", res["router_eps"], "paper~0.1")]


def bench_kernels(res):
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.router_score.kernel import router_score_fused
    from repro.kernels.mlstm_scan.ops import mlstm_chunkwise
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 7)

    def timeit(fn, *args, n=3):
        fn(*args)  # compile
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(fn(*args))
        return (time.time() - t0) / n * 1e6

    q = jax.random.normal(ks[0], (2, 256, 4, 64))
    k = jax.random.normal(ks[1], (2, 256, 2, 64))
    us = timeit(lambda a, b: flash_attention(a, b, b, block_q=64, block_k=64),
                q, k)
    rows.append(("kernels/flash_attention_us", us, "interpret-mode 2x256x4x64"))

    emb = jax.random.normal(ks[2], (64, 128))
    w1 = jax.random.normal(ks[3], (128, 128)) * 0.1
    w2 = jax.random.normal(ks[4], (128, 11)) * 0.1
    us = timeit(lambda e: router_score_fused(
        e, w1, jnp.zeros(128), w2, jnp.zeros(11),
        jnp.zeros((1, 11)), jnp.zeros((64, 1)), block_b=64), emb)
    rows.append(("kernels/router_score_us", us, "interpret-mode 64x128"))

    qm = jax.random.normal(ks[5], (1, 128, 2, 32))
    ig = jax.random.normal(ks[6], (1, 128, 2))
    st = {"C": jnp.zeros((1, 2, 32, 32)), "n": jnp.zeros((1, 2, 32)),
          "m": jnp.zeros((1, 2))}
    us = timeit(lambda a: mlstm_chunkwise(a, a, a, ig, ig + 3, st, chunk=32), qm)
    rows.append(("kernels/mlstm_chunkwise_us", us, "interpret-mode 1x128x2x32"))
    return rows


def bench_router_decision(res):
    """Router-decision throughput, fused Pallas path vs host reference
    path, on a 256-request mixed-flag workload (choices must agree)."""
    import jax
    from repro.core.library import ExpertSpec, ModelLibrary, _enc
    from repro.core.objective import recency_constraint, size_constraint
    from repro.core.router import RouterConfig, init_router
    from repro.models.model import count_params, init_model
    from repro.serving import Request, TryageEngine

    lib = ModelLibrary([
        ExpertSpec("small", _enc("small", 1, 32, 2, 64, 64), {}, 0.5),
        ExpertSpec("mid", _enc("mid", 1, 48, 2, 96, 64), {}, 0.5),
        ExpertSpec("big", _enc("big", 2, 64, 2, 128, 64), {}, 0.9),
    ])
    for i, e in enumerate(lib.experts):
        e.params, _ = init_model(jax.random.PRNGKey(i), e.cfg)
        e.n_params = count_params(e.params)
    rc = RouterConfig(n_models=3, vocab_size=64, num_layers=1, d_model=32,
                      num_heads=2, d_ff=64)
    rp, _ = init_router(jax.random.PRNGKey(9), rc)
    cons = [size_constraint(lib), recency_constraint(lib)]

    rng = np.random.default_rng(0)
    toks = rng.integers(4, 64, size=(256, 64)).astype(np.int32)
    flag_mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]
    reqs = [Request(uid=i, tokens=toks[i],
                    lambdas=flag_mix[i % len(flag_mix)])
            for i in range(256)]
    batches = [reqs[i:i + 32] for i in range(0, 256, 32)]

    rows, choices = [], {}
    for name, use_kernel in [("host", False), ("fused", True)]:
        eng = TryageEngine(lib, rp, rc, cons, max_batch=32,
                           use_kernel=use_kernel, decision_cache=False)
        eng._route_batch(batches[0])  # compile
        t0 = time.time()
        ch = []
        for b in batches:
            _, c = eng._route_batch(b)
            ch.append(c)
        dt = time.time() - t0
        choices[name] = np.concatenate(ch)
        rows.append((f"router_decision/{name}_req_per_s", 256 / dt,
                     "256 reqs warm, batch 32"))
    match = float((choices["host"] == choices["fused"]).mean())
    rows.append(("router_decision/choice_match", match,
                 "fused vs host, must be 1"))
    return rows


def bench_serving(res):
    from repro.core import experiment as ex
    from repro.core.objective import size_constraint, recency_constraint
    from repro.serving import Request, TryageEngine
    from repro.data.batching import mlm_batch
    art = ex.load_artifacts()
    lib, rp, rc, corpus = (art["library"], art["router_params"], art["rc"],
                           art["corpus"])
    eng = TryageEngine(lib, rp, rc,
                       [size_constraint(lib), recency_constraint(lib)],
                       max_batch=32)
    rng = np.random.default_rng(0)
    uniform = {d: 1.0 / 8 for d in corpus.tables}
    toks, _ = corpus.sample_mixture(uniform, 128, 128, rng)
    mb = mlm_batch(toks, rng, 0.15, corpus.vocab_size)
    for i in range(128):
        eng.submit(Request(uid=i, tokens=mb["tokens"][i],
                           targets=mb["targets"][i], mask=mb["mask"][i],
                           lambdas={"size": 0.5} if i % 2 else {}))
    t0 = time.time()
    results = eng.run()
    dt = time.time() - t0
    accs = [r.accuracy for r in results if r.accuracy is not None]
    return [
        ("serving/requests_per_s", len(results) / dt, "128 reqs warm"),
        ("serving/mean_accuracy", float(np.mean(accs)), ""),
        ("serving/experts_used", float(len(eng.stats.per_expert)), ""),
    ]


def bench_scheduler(res):
    """Continuous-batching scheduler vs FIFO drain on the mixed-flag
    workload from launch/serve.py (25% repeated prompts so the decision
    cache sees production-shaped traffic).  Continuous batching must
    strictly reduce padded rows and match or beat FIFO throughput, and
    repeated requests must get the identical expert choice (cache
    parity)."""
    from repro.core import experiment as ex
    from repro.core.objective import recency_constraint, size_constraint
    from repro.data.batching import mlm_batch
    from repro.serving import Request, TryageEngine
    art = ex.load_artifacts()
    lib, rp, rc, corpus = (art["library"], art["router_params"], art["rc"],
                           art["corpus"])
    cons = [size_constraint(lib), recency_constraint(lib)]

    n, n_unique = 256, 192
    rng = np.random.default_rng(0)
    uniform = {d: 1.0 / 8 for d in corpus.tables}
    toks, _ = corpus.sample_mixture(uniform, n_unique, 128, rng)
    mb = mlm_batch(toks, rng, 0.15, corpus.vocab_size)
    flag_mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]

    def workload():
        # last n - n_unique requests repeat earlier prompts + lambdas
        return [Request(uid=i, tokens=mb["tokens"][i % n_unique],
                        targets=mb["targets"][i % n_unique],
                        mask=mb["mask"][i % n_unique],
                        lambdas=flag_mix[i % len(flag_mix)])
                for i in range(n)]

    def engine():
        return TryageEngine(lib, rp, rc, cons, max_batch=32,
                            max_wait_s=10.0)

    def reset(eng):
        # fresh stats and a cold decision cache so the timed pass sees
        # exactly the 64/256 repeated prompts, not the warmup's entries
        eng.stats = type(eng.stats)()
        eng.cache = type(eng.cache)(eng.cache.capacity)

    # FIFO drain ---------------------------------------------------------
    fifo = engine()
    for r in workload():                       # warm the jit caches
        fifo.submit(r)
    fifo.run()
    reset(fifo)
    for r in workload():
        fifo.submit(r)
    t0 = time.time()
    res_fifo = fifo.run()
    dt_fifo = time.time() - t0

    # continuous batching ------------------------------------------------
    cb = engine()
    list(cb.serve(iter(workload())))           # warm the jit caches
    reset(cb)
    t0 = time.time()
    res_cb = list(cb.serve(iter(workload())))
    dt_cb = time.time() - t0

    by_uid = {r.uid: r.expert for r in res_cb}
    parity = float(all(by_uid[i] == by_uid[i % n_unique] for i in range(n)))
    match = float(all(by_uid[r.uid] == r.expert for r in res_fifo))
    lat = cb.stats.latency_percentiles()
    return [
        ("scheduler/fifo_req_per_s", n / dt_fifo, "256 reqs warm, batch 32"),
        ("scheduler/stream_req_per_s", n / dt_cb, "continuous batching"),
        ("scheduler/fifo_padded_rows", float(fifo.stats.padded_rows), ""),
        ("scheduler/stream_padded_rows", float(cb.stats.padded_rows),
         "must be < fifo"),
        ("scheduler/padded_rows_saved",
         float(fifo.stats.padded_rows - cb.stats.padded_rows),
         "must be > 0"),
        ("scheduler/cache_hit_rate", cb.stats.cache_hit_rate,
         "64/256 repeated prompts"),
        ("scheduler/cache_parity", parity, "repeats choose same expert"),
        ("scheduler/discipline_choice_match", match, "fifo vs stream"),
        ("scheduler/stream_p50_latency_s", lat["p50_s"], ""),
        ("scheduler/stream_p95_latency_s", lat["p95_s"], ""),
    ]


def bench_cascade(res):
    """Cascade routing vs single-shot on the mixed-flag 256-request
    workload: the accuracy-vs-mean-selected-size front.

    Single-shot operating points come from sweeping an extra size-
    penalty lambda on top of the mixed user flags (the paper's Pareto
    knob).  Cascade points fix a strong small-model bias (lambda = 8)
    and sweep the per-request confidence threshold: requests whose
    chosen expert the router distrusts escalate to the next-larger
    expert, spending parameters only where the router expects to be
    wrong.  Cascade must strictly dominate at least one single-shot
    point (>= accuracy at <= mean size, strict in one coordinate) —
    a generator so every measured row is emitted before the gate
    raises; under --strict a non-dominating front fails the run.
    """
    from repro.core import experiment as ex
    from repro.core.objective import recency_constraint, size_constraint
    from repro.core.training import calibrate_uncertainty
    from repro.data.batching import mlm_batch
    from repro.serving import Request, TryageEngine
    _require_artifact_quality(res, "cascade")
    art = ex.load_artifacts()
    lib, rp, rc, corpus = (art["library"], art["router_params"], art["rc"],
                           art["corpus"])
    if "unc" not in rp:
        rp = calibrate_uncertainty(rp, rc, art["test_tokens"],
                                   art["q_test"]["loss"])
    cons = [size_constraint(lib), recency_constraint(lib)]
    sizes = {e.name: e.n_params for e in lib.experts}
    max_size = max(sizes.values())

    n = 256
    rng = np.random.default_rng(0)
    uniform = {d: 1.0 / 8 for d in corpus.tables}
    toks, _ = corpus.sample_mixture(uniform, n, 128, rng)
    mb = mlm_batch(toks, rng, 0.15, corpus.vocab_size)
    flag_mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]

    def workload(extra_size_lam=0.0, min_conf=0.0):
        reqs = []
        for i in range(n):
            lam = dict(flag_mix[i % len(flag_mix)])
            if extra_size_lam:
                lam["size"] = lam.get("size", 0.0) + extra_size_lam
            reqs.append(Request(
                uid=i, tokens=mb["tokens"][i], targets=mb["targets"][i],
                mask=mb["mask"][i], lambdas=lam, min_confidence=min_conf))
        return reqs

    eng = TryageEngine(lib, rp, rc, cons, max_batch=32,
                       cascade_max_depth=3)

    def run_point(reqs):
        eng.stats = type(eng.stats)()
        eng.cache = type(eng.cache)(eng.cache.capacity)
        for r in reqs:
            eng.submit(r)
        results = eng.run()
        accs = [r.accuracy for r in results if r.accuracy is not None]
        msize = np.mean([sizes[r.expert] for r in results]) / max_size
        return float(np.mean(accs)), float(msize), eng.stats

    single, casc = [], []
    for lam in (0.0, 1.0, 4.0, 8.0):
        acc, msize, _ = run_point(workload(extra_size_lam=lam))
        single.append((acc, msize))
        yield (f"cascade/single_shot/lam_{lam:g}/accuracy", acc,
               f"mean_size_frac={msize:.4f}")

    # cascade thresholds from the workload's own confidence quantiles:
    # escalate roughly the least-confident 25/50/75/100% of requests
    # rather than guessing absolute confidence values
    base = workload(extra_size_lam=8.0)
    confs = []
    for i in range(0, n, 32):
        chunk = base[i:i + 32]
        _, choice = eng._score_batch(chunk)
        conf = 1.0 / (1.0 + eng._sigma_batch(chunk))
        confs.extend(float(conf[j, c]) for j, c in enumerate(choice))
    quants = {"q25": 0.25, "q50": 0.5, "q75": 0.75, "q100": 1.0}
    for qname, q in quants.items():
        t = float(np.quantile(confs, q)) + 1e-6
        acc, msize, stats = run_point(
            workload(extra_size_lam=8.0, min_conf=t))
        casc.append((acc, msize))
        hist = ";".join(f"d{k}:{v}" for k, v in
                        sorted(stats.cascade_depth_hist.items()))
        yield (f"cascade/cascade/{qname}/accuracy", acc,
               f"mean_size_frac={msize:.4f};threshold={t:.4f}")
        yield (f"cascade/cascade/{qname}/escalations",
               float(stats.escalations), hist)

    # strict-domination gate: some cascade point at least matches a
    # single-shot point in both coordinates and beats it in one
    witness = ""
    dominates = 0.0
    for ca, cs in casc:
        for sa, ss in single:
            if ca >= sa and cs <= ss and (ca > sa or cs < ss):
                dominates = 1.0
                witness = (f"cascade({ca:.4f};{cs:.4f}) beats "
                           f"single({sa:.4f};{ss:.4f})")
                break
        if dominates:
            break
    yield ("cascade/dominates_single_shot", dominates,
           witness or "no dominating operating point")
    if not dominates:
        raise RuntimeError(
            "cascade front does not dominate any single-shot point")


def bench_drift(res):
    """Online router adaptation under a mid-stream shift.

    Scenario (the paper's motivating failure mode: downstream expert
    performance drifts while the router's knowledge goes stale):

      1. *Pre-shift*: traffic samples the uniform domain mix; every
         expert behaves as it did when the router was trained.
      2. *Shift*: the traffic mix concentrates on the home domains of
         the router's favourite expert E, and — simultaneously — E's
         deployment regresses (its weights are replaced by a fresh
         init, a stale/bad rollout).  The frozen router keeps routing
         that traffic to E on stale predictions.
      3. *Post-shift*: a frozen engine and an adapting engine
         (``adapt_every=8``, head-only incremental updates on execution
         feedback) serve identical request streams; routing accuracy is
         measured per 32-request window against the *current* ground
         truth (E's true losses recomputed after the regression).

    Routing accuracy is the repo's tolerant selection accuracy (picked
    expert within 0.5 nats of the per-prompt optimum — exact-argmin
    matching is noise at this scale, see ``core.baselines``).  Gates:
    the frozen engine must stay degraded after the shift, the adapting
    engine must recover at least half of the drop, and every router
    update must have bumped the version.  The per-window timeline is
    written to ``experiments/tryage/drift_timeline.csv`` (CI uploads
    it next to the benchmark CSV).
    """
    import jax
    from repro.core import experiment as ex
    from repro.core.experiment import _eval_batches
    from repro.core.qtable import _per_prompt_metrics_jit
    from repro.data.corpus import DOMAINS
    from repro.models.model import init_model
    from repro.serving import Request, TryageEngine

    import jax.numpy as jnp

    _require_artifact_quality(res, "drift")
    art = ex.load_artifacts()
    lib, rp, rc, corpus = (art["library"], art["router_params"], art["rc"],
                           art["corpus"])
    cfg = res["config"]

    # rebuild the held-out eval batches (deterministic seeds) so the
    # workload carries targets/mask for execution feedback; they must
    # line up with the cached Q-table's rows
    test_b = []
    for di, d in enumerate(DOMAINS):
        test_b += _eval_batches(corpus, {d: 1.0}, cfg["n_test_per_domain"],
                                cfg["seq"], cfg["seed"] + 303 + di)
    cat = lambda k: np.concatenate([b[k] for b in test_b])
    tokens, targets, mask, domain = (cat("tokens"), cat("targets"),
                                     cat("mask"), cat("domain"))
    if tokens.shape != art["test_tokens"].shape or \
            not (tokens == art["test_tokens"]).all():
        raise RuntimeError(
            "drift: rebuilt eval batches do not match cached test_tokens "
            "(artifacts.pkl and results.json are from different runs?) — "
            "regenerate the artifacts")
    q_pre = art["q_test"]["loss"]                       # (N, M) truth
    pred = art["pred"]                                  # router L-hat

    TOL = 0.5          # "routed well" = within 0.5 nats of the optimum
    names = [e.name for e in lib.experts]
    name2idx = {n: i for i, n in enumerate(names)}
    choice0 = pred.argmin(1)
    E = int(np.bincount(choice0, minlength=len(lib)).argmax())
    # shift domains: where the favourite expert is both routed to and
    # genuinely near-optimal pre-drift, so pre-drift routing of the
    # shifted traffic was *good* and the post-drift drop is real
    good_E = (choice0 == E) & (q_pre[:, E] <= q_pre.min(1) + TOL)
    dom_counts = np.array([(good_E & (domain == di)).sum()
                           for di in range(len(DOMAINS))])
    D = sorted(np.argsort(dom_counts)[::-1][:2].tolist())
    pool_pre = np.arange(len(tokens))
    pool_post = np.where(np.isin(domain, D))[0]

    # the regression: E's deployment rolls back to a fresh init; its
    # true per-prompt losses are recomputed for the post-shift truth
    orig_params = lib.experts[E].params
    bad_params, _ = init_model(jax.random.PRNGKey(4321), lib.experts[E].cfg)
    newloss = []
    for b in test_b:
        jb = {k: jnp.asarray(v) for k, v in b.items() if k != "domain"}
        l, _ = _per_prompt_metrics_jit(bad_params, lib.experts[E].cfg, jb)
        newloss.append(np.asarray(l))
    q_post = q_pre.copy()
    q_post[:, E] = np.concatenate(newloss)

    W, n_pre, n_post = 32, 96, 288

    def tolacc(choices, idx, L):
        picked = L[idx, choices]
        return float((picked <= L[idx].min(1) + TOL).mean())

    def timeline(adapt: bool):
        """Serve the two-phase stream; returns (pre_accs, post_accs,
        post window choices+indices, engine)."""
        rng = np.random.default_rng(0)
        eng = TryageEngine(
            lib, rp, rc, [], max_batch=32,
            adapt_every=8 if adapt else 0, adapt_lr=0.1,
            adapt_trainable="head", adapt_batch=32, replay_cap=128)
        uid = 0

        def window(pool, L):
            nonlocal uid
            idx = rng.choice(pool, size=W, replace=len(pool) < W)
            for i in idx:
                eng.submit(Request(uid=uid, tokens=tokens[i],
                                   targets=targets[i], mask=mask[i]))
                uid += 1
            out = sorted(eng.run(), key=lambda r: r.uid)
            ch = np.array([name2idx[r.expert] for r in out])
            return tolacc(ch, idx, L), ch, idx

        try:
            lib.experts[E].params = orig_params
            pre = [window(pool_pre, q_pre)[0] for _ in range(n_pre // W)]
            lib.experts[E].params = bad_params
            post, post_ch = [], []
            for _ in range(n_post // W):
                acc, ch, idx = window(pool_post, q_post)
                post.append(acc)
                post_ch.append((ch, idx))
            return pre, post, post_ch, eng
        finally:
            lib.experts[E].params = orig_params

    pre_f, post_f, post_ch_f, frozen = timeline(adapt=False)
    pre_a, post_a, _, adapting = timeline(adapt=True)

    # what the frozen router's post-shift choices were worth *before*
    # the drift: the pre-drift accuracy of the shifted traffic, i.e.
    # the level the drop is measured from
    before = float(np.mean([tolacc(ch, idx, q_pre)
                            for ch, idx in post_ch_f]))
    frozen_post = float(np.mean(post_f))
    adapted_post = float(np.mean(post_a[-3:]))          # recovered level
    drop = before - frozen_post
    recovered = ((adapted_post - frozen_post) / drop) if drop > 0 else 0.0
    stats = adapting.stats.summary()["adaptation"]

    os.makedirs(ex.ART_DIR, exist_ok=True)
    csv_path = os.path.normpath(
        os.path.join(ex.ART_DIR, "drift_timeline.csv"))
    with open(csv_path, "w") as f:
        f.write("phase,window,frozen_acc,adapted_acc\n")
        for w, (af, aa) in enumerate(zip(pre_f, pre_a)):
            f.write(f"pre,{w},{af:.6g},{aa:.6g}\n")
        for w, (af, aa) in enumerate(zip(post_f, post_a)):
            f.write(f"post,{w},{af:.6g},{aa:.6g}\n")

    rows = [
        ("drift/regressed_expert", float(E), names[E]),
        ("drift/shift_domains", float(len(D)),
         ";".join(DOMAINS[d] for d in D)),
        ("drift/before_acc", before,
         "frozen post-shift choices vs pre-drift truth"),
        ("drift/frozen_post_acc", frozen_post, "must stay degraded"),
        ("drift/adapted_post_acc", adapted_post, "mean of last 3 windows"),
        ("drift/recovered_frac", recovered, "must be >= 0.5"),
        ("drift/updates", float(stats["updates"]), ""),
        ("drift/router_version", float(stats["router_version"]),
         "one bump per update"),
        ("drift/feedback_events", float(stats["feedback_events"]), ""),
        ("drift/timeline_csv", 1.0, csv_path),
    ]
    for row in rows:
        yield row
    if stats["updates"] < 1 or stats["router_version"] != stats["updates"]:
        raise RuntimeError("drift: adaptation applied no updates (or "
                           "versions out of step with updates)")
    if frozen_post > before - 0.2:
        raise RuntimeError(
            f"drift: frozen router did not degrade (before={before:.3f}, "
            f"frozen_post={frozen_post:.3f}) — shift scenario is broken")
    if recovered < 0.5:
        raise RuntimeError(
            f"drift: adapting router recovered only {recovered:.2f} of "
            f"the accuracy drop (need >= 0.5)")


def bench_slo(res):
    """Routing availability + p99 SLO under bursty arrivals with one
    expert forced unhealthy mid-stream.

    Two engines serve identical 192-request bursty streams on a
    synthetic clock (deterministic — the clock only advances in the
    arrival generator, so measured latency is pure queueing delay):

      * *fallback*: ``ExpertHealth`` attached, ``fallback_max_depth=2``.
        At the one-third mark a persistent failure injection lands on
        the router's most-picked expert; in-flight lane entries re-route
        through the fallback chain and the health tracker's failure EWMA
        plus cooldown keep route-time traffic away from the dead expert
        for the rest of the run.
      * *no-fallback*: health-unaware baseline — the same injection
        makes every post-injection flush of that expert fail terminally
        (``Result.failed``).

    Gates: the fallback engine must hold routing availability
    (served / admitted) >= 0.99 while the baseline visibly degrades
    below it, and the fallback engine's p99 enqueue->flush latency must
    stay under a generous 5x lane-deadline SLO.  The per-window
    availability timeline is written to
    ``experiments/tryage/slo_timeline.csv`` (CI uploads it next to the
    benchmark CSV).  A generator, so every measured row is emitted
    before a gate raises.
    """
    import jax
    from repro.core import experiment as ex
    from repro.core.library import ExpertSpec, ModelLibrary, _enc
    from repro.core.objective import recency_constraint, size_constraint
    from repro.core.router import RouterConfig, init_router
    from repro.models.model import count_params, init_model
    from repro.serving import ExpertHealth, Request, TryageEngine

    lib = ModelLibrary([
        ExpertSpec("small", _enc("small", 1, 32, 2, 64, 64), {}, 0.5),
        ExpertSpec("mid", _enc("mid", 1, 48, 2, 96, 64), {}, 0.5),
        ExpertSpec("big", _enc("big", 2, 64, 2, 128, 64), {}, 0.9),
    ])
    for i, e in enumerate(lib.experts):
        e.params, _ = init_model(jax.random.PRNGKey(i), e.cfg)
        e.n_params = count_params(e.params)
    rc = RouterConfig(n_models=3, vocab_size=64, num_layers=1, d_model=32,
                      num_heads=2, d_ff=64)
    rp, _ = init_router(jax.random.PRNGKey(9), rc)
    cons = [size_constraint(lib), recency_constraint(lib)]

    n, W = 192, 32
    max_wait = 0.05
    slo_s = 5 * max_wait
    rng = np.random.default_rng(0)
    toks = rng.integers(4, 64, size=(n, 64)).astype(np.int32)
    flag_mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]

    def workload():
        return [Request(uid=i, tokens=toks[i],
                        lambdas=flag_mix[i % len(flag_mix)])
                for i in range(n)]

    # bursty schedule: alternating 24-request bursts (0.5 ms gaps) and
    # quiet stretches (10 ms gaps), same for both engines
    sched_t, t = [], 0.0
    for i in range(n):
        t += 0.0005 if (i // 24) % 2 == 0 else 0.01
        sched_t.append(t)

    class Clock:
        def __init__(self):
            self.t = 0.0

        def __call__(self):
            return self.t

    fail_at = n // 3

    def run(with_fallback: bool):
        clock = Clock()
        health = (ExpertHealth(len(lib), now_fn=clock)
                  if with_fallback else None)
        eng = TryageEngine(lib, rp, rc, cons, max_batch=32,
                           max_wait_s=max_wait, decision_cache=False,
                           health=health, fallback_max_depth=2,
                           now_fn=clock)
        _, warm = eng._score_batch(workload()[:W])      # compile + prescan
        E = int(np.bincount(np.asarray(warm), minlength=len(lib)).argmax())

        def arrivals():
            for i, (r, due) in enumerate(zip(workload(), sched_t)):
                while clock.t < due:
                    clock.t = min(clock.t + 0.005, due)
                    yield None
                r.arrival = clock.t
                if i == fail_at:
                    eng.scheduler.inject_failures(E)
                yield r

        results = sorted(eng.serve(arrivals()), key=lambda r: r.uid)
        return eng, results, E

    eng_fb, res_fb, E = run(with_fallback=True)
    eng_nf, res_nf, E_nf = run(with_fallback=False)
    assert E == E_nf and len(res_fb) == len(res_nf) == n

    def avail(results):
        return 1.0 - sum(r.failed for r in results) / len(results)

    def window_avail(results, w):
        return avail([r for r in results if r.uid // W == w])

    os.makedirs(ex.ART_DIR, exist_ok=True)
    csv_path = os.path.normpath(
        os.path.join(ex.ART_DIR, "slo_timeline.csv"))
    with open(csv_path, "w") as f:
        f.write("window,fallback_avail,nofallback_avail\n")
        for w in range(n // W):
            f.write(f"{w},{window_avail(res_fb, w):.6g},"
                    f"{window_avail(res_nf, w):.6g}\n")

    a_fb, a_nf = avail(res_fb), avail(res_nf)
    p99 = float(np.percentile(np.asarray(eng_fb.stats.latencies), 99))
    st = eng_fb.stats
    yield ("slo/failed_expert", float(E), lib.experts[E].name)
    yield ("slo/fallback_availability", a_fb, "must be >= 0.99")
    yield ("slo/nofallback_availability", a_nf,
           "must degrade below the fallback engine")
    yield ("slo/fallback_p99_latency_s", p99,
           f"synthetic clock; SLO {slo_s:g}s")
    yield ("slo/fallbacks", float(st.fallbacks), "route-time re-selections")
    yield ("slo/reroutes", float(st.reroutes), "failed-flush re-routes")
    yield ("slo/degraded", float(st.degraded), "")
    yield ("slo/failed_requests", float(st.failed), "")
    yield ("slo/nofallback_failed", float(eng_nf.stats.failed), "")
    yield ("slo/timeline_csv", 1.0, csv_path)
    if a_fb < 0.99:
        raise RuntimeError(
            f"slo: fallback engine availability {a_fb:.4f} < 0.99")
    if a_nf >= a_fb:
        raise RuntimeError(
            f"slo: no-fallback baseline did not degrade "
            f"(fallback={a_fb:.4f}, nofallback={a_nf:.4f}) — the failure "
            f"injection is not biting")
    if p99 > slo_s:
        raise RuntimeError(
            f"slo: fallback p99 latency {p99:.4f}s exceeds the "
            f"{slo_s:g}s SLO")


def bench_mesh(res):
    """Sharded Execute-stage scaling across simulated mesh sizes.

    One engine per mesh size (1, 2, 4, 8 devices; size 8 is a (2, 4)
    mesh so the data-parallel routing path is exercised too, the rest
    are (1, k)) serves the same 256-request mixed-flag workload over an
    8-expert synthetic library.  Placement is traffic-aware: a prescan
    of the routing choices feeds ``plan_placement`` so the greedy LPT
    assignment balances *expected compute*, and the two hottest experts
    are replicated onto every slice (flushes pick the least-busy
    replica stream).

    Throughput is *simulated overlapped* flushed-tokens/s: each flush's
    measured wall time is charged to the device stream it was
    dispatched to (``serving.placement.StreamClock``), and the makespan
    is the busiest stream's total — what a real multi-device runtime,
    which genuinely overlaps independent per-device programs, would
    take.  One physical CPU executes the streams serially, so raw wall
    time cannot show the overlap; the per-stream accounting can, and
    the per-flush numerics are identical either way (committed
    single-device execution).

    Gates: simulated flushed-tokens/s at mesh size 4 must be >= 3x mesh
    size 1, and routing choices must be identical across all sizes.
    Per-size rows land in ``experiments/tryage/mesh_scaling.csv`` (CI
    uploads it).  Needs >= 4 visible devices for the gate — run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; sizes the
    host cannot back are skipped (reported, not failed).
    """
    import jax
    from repro.core import experiment as ex
    from repro.core.library import ExpertSpec, ModelLibrary, _enc
    from repro.core.objective import recency_constraint, size_constraint
    from repro.core.router import RouterConfig, init_router
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import count_params, init_model
    from repro.serving import Request, TryageEngine
    from repro.serving.placement import plan_placement

    M, n, S = 8, 256, 64
    specs = []
    for i in range(M):
        d = 32 + 16 * (i % 4)
        layers = 1 + i // 4
        specs.append(ExpertSpec(f"e{i}", _enc(f"e{i}", layers, d, 2,
                                              2 * d, S), {},
                                0.5 + 0.05 * i))
    lib = ModelLibrary(specs)
    for i, e in enumerate(lib.experts):
        e.params, _ = init_model(jax.random.PRNGKey(i), e.cfg)
        e.n_params = count_params(e.params)
    rc = RouterConfig(n_models=M, vocab_size=64, num_layers=1, d_model=32,
                      num_heads=2, d_ff=64)
    rp, _ = init_router(jax.random.PRNGKey(9), rc)
    cons = [size_constraint(lib), recency_constraint(lib)]

    rng = np.random.default_rng(0)
    toks = rng.integers(4, 64, size=(n, S)).astype(np.int32)
    flag_mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]

    def workload():
        return [Request(uid=i, tokens=toks[i],
                        lambdas=flag_mix[i % len(flag_mix)])
                for i in range(n)]

    # traffic prescan: the routing choices the workload will actually
    # make, so placement balances expected compute (sizes alone would
    # balance resident bytes while the router concentrates traffic)
    scout = TryageEngine(lib, rp, rc, cons, max_batch=32, use_kernel=True,
                         decision_cache=False)
    w = workload()
    picks = np.concatenate([scout._score_batch(w[i:i + 32])[1]
                            for i in range(0, n, 32)])
    traffic = np.bincount(picks, minlength=M) / float(n)
    sizes = [e.n_params for e in lib.experts]

    all_sizes = [1, 2, 4] if _FAST["fast"] else [1, 2, 4, 8]
    shapes = {1: (1, 1), 2: (1, 2), 4: (1, 4), 8: (2, 4)}
    have = jax.device_count()
    runnable = [k for k in all_sizes if k <= have]
    for k in sorted(set(all_sizes) - set(runnable)):
        yield (f"mesh/size{k}_skipped", 1.0,
               f"needs {k} devices, have {have} — set XLA_FLAGS="
               f"--xla_force_host_platform_device_count=8")

    tput, choices, csv_rows = {}, {}, []
    for k in runnable:
        data, model = shapes[k]
        mesh = make_host_mesh(data, model)
        placement = plan_placement(sizes, model, replicate_hot=2,
                                   traffic=traffic)
        eng = TryageEngine(lib, rp, rc, cons, max_batch=32,
                           use_kernel=True, decision_cache=False,
                           lane_target=8, max_wait_s=10.0, mesh=mesh,
                           placement=placement)
        list(eng.serve(iter(workload())))    # warm the routing path
        eng.warm_mesh(S)                     # compile every (expert,
        eng.streams.reset()                  # device, bucket) variant
        t0 = time.time()
        results = list(eng.serve(iter(workload())))
        wall = time.time() - t0
        assert len(results) == n
        choices[k] = [r.expert for r in sorted(results,
                                               key=lambda r: r.uid)]
        st = eng.streams
        tokens = sum(st.tokens)
        tput[k] = tokens / st.makespan_s
        csv_rows.append((k, st.n_streams, tokens, st.makespan_s,
                         st.total_busy_s, tput[k], wall))
        yield (f"mesh/size{k}_tokens_per_s", tput[k],
               f"simulated overlap, {data}x{model} mesh")
        yield (f"mesh/size{k}_makespan_s", st.makespan_s,
               "busiest stream")

    os.makedirs(ex.ART_DIR, exist_ok=True)
    csv_path = os.path.normpath(
        os.path.join(ex.ART_DIR, "mesh_scaling.csv"))
    with open(csv_path, "w") as f:
        f.write("mesh_size,streams,tokens,makespan_s,total_busy_s,"
                "tokens_per_s,wall_s\n")
        for row in csv_rows:
            f.write(",".join(f"{v:.6g}" if isinstance(v, float) else str(v)
                             for v in row) + "\n")
    yield ("mesh/scaling_csv", 1.0, csv_path)

    base = runnable[0]
    match = float(all(choices[k] == choices[base] for k in runnable))
    yield ("mesh/choice_match", match, "across mesh sizes, must be 1")
    if match != 1.0:
        raise RuntimeError("mesh: routing choices diverged across mesh "
                           "sizes — placement must never change routing")
    if 4 in tput and 1 in tput:
        ratio = tput[4] / tput[1]
        yield ("mesh/scaling_4x", ratio, "size 4 vs 1, must be >= 3")
        if ratio < 3.0:
            raise RuntimeError(
                f"mesh: simulated flushed-tokens/s at mesh size 4 is "
                f"only {ratio:.2f}x size 1 (need >= 3x)")


def bench_cache(res):
    """Tiered decision cache (T1 exact LRU + T2 persistent KV + T3
    semantic) on repeated/paraphrased traffic.

    The workload is production-shaped: a stream of unique prompts, then
    exact repeats (retries/polling — what T1/T2 answer), then
    paraphrases made by flipping one token of an earlier prompt (what
    only the semantic tier can answer; the exact tiers key on token
    bytes and must miss them).  The semantic distance bound is
    *calibrated*, not hand-picked: ``calibrate_eps`` over the fresh
    verdicts of the unique prefix (half the smallest distance between
    any two disagreeing prompts).

    Gates (--strict fails the run):
      * combined T1+T2+T3 hit-rate >= 2x the exact-only engine's on the
        identical stream;
      * zero wrong routings: every expert choice the tiered engine
        serves (from any tier) equals a fresh-scoring oracle engine's
        choice for the same request;
      * mean decision time (router seconds per request) improves on the
        exact-only engine;
      * T2 restart round-trip: a new engine over the same ``DiskKVStore``
        directory serves the stream again at >= 0.99 hit-rate (verdicts
        survive the process).

    Per-engine rows land in ``experiments/tryage/cache_hits.csv`` (CI
    uploads it next to the benchmark CSV).
    """
    import shutil
    import tempfile

    import jax
    from repro.core import experiment as ex
    from repro.core.library import ExpertSpec, ModelLibrary, _enc
    from repro.core.objective import recency_constraint, size_constraint
    from repro.core.router import RouterConfig, init_router
    from repro.models.model import count_params, init_model
    from repro.serving import Request, TryageEngine, calibrate_eps
    from repro.serving.engine import EngineStats

    lib = ModelLibrary([
        ExpertSpec("small", _enc("small", 1, 32, 2, 64, 64), {}, 0.5),
        ExpertSpec("mid", _enc("mid", 1, 48, 2, 96, 64), {}, 0.5),
        ExpertSpec("big", _enc("big", 2, 64, 2, 128, 64), {}, 0.9),
    ])
    for i, e in enumerate(lib.experts):
        e.params, _ = init_model(jax.random.PRNGKey(i), e.cfg)
        e.n_params = count_params(e.params)
    rc = RouterConfig(n_models=3, vocab_size=64, num_layers=1, d_model=32,
                      num_heads=2, d_ff=64)
    rp, _ = init_router(jax.random.PRNGKey(9), rc)
    cons = [size_constraint(lib), recency_constraint(lib)]

    n_unique = 48 if _FAST["fast"] else 96
    n_repeat, n_para = (32, 48) if _FAST["fast"] else (64, 96)
    S = 32
    rng = np.random.default_rng(0)
    toks = rng.integers(4, 64, size=(n_unique, S)).astype(np.int32)
    para = toks[np.arange(n_para) % n_unique].copy()
    for i in range(n_para):                # paraphrase: flip one token
        para[i, rng.integers(0, S)] = rng.integers(4, 64)
    flag_mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]
    n = n_unique + n_repeat + n_para

    def workload():
        stream = [toks[i] for i in range(n_unique)]
        stream += [toks[i % n_unique] for i in range(n_repeat)]
        stream += [para[i] for i in range(n_para)]
        return [Request(uid=i, tokens=t,
                        lambdas=flag_mix[i % len(flag_mix)])
                for i, t in enumerate(stream)]

    def engine(**kw):
        return TryageEngine(lib, rp, rc, cons, max_batch=32, **kw)

    def run_measured(eng):
        """Serve the stream with warm jits; return results by uid."""
        warm = rng.integers(4, 64, size=(8, S)).astype(np.int32)
        for i in range(8):                 # trace/compile outside timing
            eng.submit(Request(uid=-1 - i, tokens=warm[i]))
        eng.run()
        eng.cache.clear()
        eng.stats = EngineStats()
        for r in workload():
            eng.submit(r)
        return {r.uid: r for r in eng.run()}

    # fresh-scoring oracle: no cache at all, every verdict recomputed
    oracle_eng = engine(decision_cache=False)
    for r in workload():
        oracle_eng.submit(r)
    oracle = {r.uid: r for r in oracle_eng.run()}

    # calibrate the semantic bound on the unique prefix's fresh verdicts,
    # per lambda context (T3 indexes per context, so only same-context
    # disagreements constrain the bound — pooling contexts would shrink
    # eps with disagreements the tier can never cross)
    uniq = workload()[:n_unique]
    emb = oracle_eng._embed_batch(uniq)
    choices = np.array([oracle[r.uid].expert for r in uniq])
    ctx = np.array([i % len(flag_mix) for i in range(n_unique)])
    eps = min(calibrate_eps(emb[ctx == c], choices[ctx == c], margin=0.5)
              for c in range(len(flag_mix)))
    if not np.isfinite(eps):               # all verdicts agree: bound by
        d = ((emb[:, None] - emb[None]) ** 2).sum(-1)  # the sample itself
        eps = 0.5 * float(np.sqrt(np.median(d[d > 0])))
    yield ("cache/calibrated_eps", eps,
           "0.5x closest same-context disagreeing pair")

    csv_rows = []

    def measure(tag, eng):
        out = run_measured(eng)
        st = eng.stats
        total = st.cache_hits + st.cache_misses
        hit_rate = st.cache_hits / max(1, total)
        dec_ms = 1e3 * st.router_time_s / max(1, len(out))
        wrong = sum(out[u].expert != oracle[u].expert for u in out)
        tiers = dict(st.cache_tier_hits)
        csv_rows.append((tag, hit_rate, tiers.get("t1", 0),
                         tiers.get("t2", 0), tiers.get("t3", 0),
                         st.cache_revalidation_rejects, dec_ms, wrong))
        return hit_rate, dec_ms, wrong

    exact_rate, exact_ms, exact_wrong = measure("exact", engine())

    cache_dir = tempfile.mkdtemp(prefix="bench_cache_")
    try:
        tiered = engine(cache_dir=cache_dir, cache_semantic_eps=eps)
        tier_rate, tier_ms, tier_wrong = measure("tiered", tiered)
        tiered.cache.close()
        restart = engine(cache_dir=cache_dir, cache_semantic_eps=eps)
        re_rate, _, re_wrong = measure("restart", restart)
        restart.cache.close()
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)

    os.makedirs(ex.ART_DIR, exist_ok=True)
    csv_path = os.path.normpath(os.path.join(ex.ART_DIR, "cache_hits.csv"))
    with open(csv_path, "w") as f:
        f.write("engine,hit_rate,t1_hits,t2_hits,t3_hits,"
                "revalidation_rejects,decision_ms,wrong_verdicts\n")
        for row in csv_rows:
            f.write(",".join(f"{v:.6g}" if isinstance(v, float) else str(v)
                             for v in row) + "\n")
    yield ("cache/hits_csv", 1.0, csv_path)

    yield ("cache/hit_rate_exact", exact_rate, f"{n} reqs, repeats only")
    yield ("cache/hit_rate_tiered", tier_rate,
           "same stream, T1+T2+T3, must be >= 2x exact")
    yield ("cache/decision_ms_exact", exact_ms, "router s/request")
    yield ("cache/decision_ms_tiered", tier_ms, "must improve on exact")
    yield ("cache/wrong_verdicts", float(tier_wrong + re_wrong + exact_wrong),
           "vs fresh-score oracle, must be 0")
    yield ("cache/restart_hit_rate", re_rate,
           "new process over the same DiskKVStore, must be >= 0.99")

    if tier_wrong or re_wrong or exact_wrong:
        raise RuntimeError(
            f"cache: {tier_wrong + re_wrong + exact_wrong} served verdicts "
            f"disagree with the fresh-score oracle (must be 0)")
    if tier_rate < 2 * exact_rate:
        raise RuntimeError(
            f"cache: tiered hit-rate {tier_rate:.3f} < 2x exact-only "
            f"{exact_rate:.3f}")
    if tier_ms >= exact_ms:
        raise RuntimeError(
            f"cache: tiered decision time {tier_ms:.3f} ms/req did not "
            f"improve on exact-only {exact_ms:.3f} ms/req")
    if re_rate < 0.99:
        raise RuntimeError(
            f"cache: restart hit-rate {re_rate:.3f} < 0.99 — the "
            f"DiskKVStore round-trip lost verdicts")


def bench_decision_latency(res):
    """One-launch fused cascade vs the staged decide/sigma/escalate
    path: p50/p99 decision latency at serving batch sizes with
    escalation traffic (~half the workload carries its median-confidence
    threshold, so depth-1 escalations actually fire).

    Gates: expert choices and cascade depths must be bit-identical
    between the two paths at every batch point, and the fused path's p50
    must beat the staged path at the largest batch (>= 4k in full mode).
    Also times the autotuned router tile against the static
    ``block_b=128`` default — the tuned tile must win on at least one
    batch point (regenerate the table with ``python -m
    repro.launch.autotune``).  Per-point rows land in
    experiments/tryage/decision_latency.csv.
    """
    import jax
    from repro.core.library import ExpertSpec, ModelLibrary, _enc
    from repro.core.router import RouterConfig, init_router
    from repro.kernels.router_score import ops as rs_ops
    from repro.models.model import count_params, init_model
    from repro.serving import Request, TryageEngine

    fast = _FAST["fast"]
    batches = (256, 512) if fast else (1000, 4000, 16000)
    repeats = 5 if fast else 7

    lib = ModelLibrary([
        ExpertSpec("small", _enc("small", 1, 32, 2, 64, 64), {}, 0.5),
        ExpertSpec("mid", _enc("mid", 1, 48, 2, 96, 64), {}, 0.5),
        ExpertSpec("big", _enc("big", 2, 64, 2, 128, 64), {}, 0.9),
    ])
    for i, e in enumerate(lib.experts):
        e.params, _ = init_model(jax.random.PRNGKey(i), e.cfg)
        e.n_params = count_params(e.params)
    rc = RouterConfig(n_models=3, vocab_size=64, num_layers=1, d_model=32,
                      num_heads=2, d_ff=64)
    rp, _ = init_router(jax.random.PRNGKey(9), rc, uncertainty=True)

    rng = np.random.default_rng(0)

    def engine(fused):
        return TryageEngine(lib, rp, rc, use_kernel=True,
                            decision_cache=False, cascade_max_depth=2,
                            fused_cascade=fused)

    staged, fused = engine(False), engine(True)

    # escalation threshold from the traffic's own confidence median
    # (bench_cascade's quantile trick): odd rows carry it, even rows
    # stay single-shot, so both code paths see mixed traffic
    probe = [Request(uid=i, tokens=rng.integers(4, 64, size=32)
                     .astype(np.int32)) for i in range(256)]
    _, pchoice = staged._score_batch(probe)
    pconf = 1.0 / (1.0 + staged._sigma_batch(probe))
    thr = float(np.quantile(
        [pconf[j, c] for j, c in enumerate(pchoice)], 0.5)) + 1e-6

    def workload(B):
        toks = rng.integers(4, 64, size=(B, 32)).astype(np.int32)
        return [Request(uid=i, tokens=toks[i],
                        min_confidence=thr if i % 2 else 0.0)
                for i in range(B)]

    def time_path(eng, reqs):
        out = eng._route_admitted(reqs)        # warm the jit caches
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = eng._route_admitted(reqs)
            ts.append((time.perf_counter() - t0) * 1e3)
        return out, (float(np.percentile(ts, 50)),
                     float(np.percentile(ts, 99)))

    csv = ["batch,path,p50_ms,p99_ms"]
    speedup_at = {}
    tile_speedups = {}
    for B in batches:
        reqs = workload(B)
        (_, c_s, _, d_s, _, _), (s50, s99) = time_path(staged, reqs)
        (_, c_f, _, d_f, _, _), (f50, f99) = time_path(fused, reqs)
        match = float(np.array_equal(c_s, c_f)
                      and np.array_equal(d_s, d_f))
        esc_frac = float((np.asarray(d_s) > 0).mean())
        csv.append(f"{B},staged,{s50:.4f},{s99:.4f}")
        csv.append(f"{B},fused,{f50:.4f},{f99:.4f}")
        yield (f"decision_latency/staged/b{B}/p50_ms", s50,
               f"p99={s99:.4f};esc_frac={esc_frac:.3f}")
        yield (f"decision_latency/fused/b{B}/p50_ms", f50,
               f"p99={f99:.4f}")
        yield (f"decision_latency/b{B}/choice_match", match,
               "choices+depths, fused vs staged, must be 1")
        speedup_at[B] = s50 / f50 if f50 > 0 else float("inf")
        yield (f"decision_latency/b{B}/speedup_p50", speedup_at[B],
               "staged_p50 / fused_p50")
        if not match:
            raise RuntimeError(
                f"decision_latency: fused cascade choices/depths "
                f"diverged from staged path at batch {B}")

        # autotuned tile vs the static default, measured on the
        # autotuner's own representative workload (the shape the table
        # entry is a claim about); decision_plan reports the *effective*
        # tile the table would apply at this batch
        from repro.launch import autotune as at
        tuned = rs_ops.decision_plan(B)["block_b"]
        cands = at.KERNELS["router_score"][0](B,
                                              np.random.default_rng(B))
        by_eff = {c.record["effective_block_b"]: c for c in cands}
        if tuned != 128 and tuned in by_eff and 128 in by_eff:
            default_ms = at.measure_candidate(by_eff[128], repeats) * 1e3
            tuned_ms = at.measure_candidate(by_eff[tuned], repeats) * 1e3
            tile_speedups[B] = default_ms / tuned_ms
            yield (f"decision_latency/b{B}/tuned_tile_speedup",
                   tile_speedups[B],
                   f"block_b {tuned} vs 128; default={default_ms:.4f}ms")
        else:
            yield (f"decision_latency/b{B}/tuned_tile_speedup", 1.0,
                   f"effective tile {tuned}; no distinct candidate pair")

    os.makedirs(os.path.join("experiments", "tryage"), exist_ok=True)
    path = os.path.join("experiments", "tryage", "decision_latency.csv")
    with open(path, "w") as f:
        f.write("\n".join(csv) + "\n")

    big = max(batches)
    if speedup_at[big] <= 1.0:
        raise RuntimeError(
            f"decision_latency: fused cascade p50 did not beat the "
            f"staged path at batch {big} "
            f"(speedup {speedup_at[big]:.3f}x)")
    if tile_speedups and max(tile_speedups.values()) <= 1.0:
        raise RuntimeError(
            "decision_latency: autotuned tile beat the static "
            "block_b=128 default at no batch point — regenerate the "
            "table with: python -m repro.launch.autotune")


# (name, fn, needs_experiment_artifacts)
BENCHES = [
    ("fig2", bench_fig2, True),
    ("fig3a", bench_fig3a, True),
    ("fig3a_mixed", bench_fig3a_mixed, True),
    ("fig3b", bench_fig3b, True),
    ("fig3cd", bench_fig3cd, True),
    ("fig4", bench_fig4, True),
    ("fig5", bench_fig5, True),
    ("router_eps", bench_router_eps, True),
    ("kernels", bench_kernels, False),
    ("router_decision", bench_router_decision, False),
    ("decision_latency", bench_decision_latency, False),
    ("serving", bench_serving, True),
    ("scheduler", bench_scheduler, True),
    ("cascade", bench_cascade, True),
    ("drift", bench_drift, True),
    ("slo", bench_slo, False),
    ("mesh", bench_mesh, False),
    ("cache", bench_cache, False),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default="",
                    help="comma-separated benchmark names "
                         "(default: run all)")
    ap.add_argument("--out", type=str, default="",
                    help="also write the CSV rows to this file")
    ap.add_argument("--fast", action="store_true",
                    help="smaller fallback experiment when artifacts are "
                         "missing; self-scaling benchmarks (mesh) also "
                         "shrink")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero if any selected benchmark errors "
                         "(CI smoke mode)")
    args = ap.parse_args(argv)
    _FAST["fast"] = args.fast

    selected = [x.strip() for x in args.only.split(",") if x.strip()]
    unknown = set(selected) - {name for name, _, _ in BENCHES}
    if unknown:
        raise SystemExit(f"unknown benchmarks: {sorted(unknown)}")
    benches = [(n, f, needs) for n, f, needs in BENCHES
               if not selected or n in selected]

    res = None
    if any(needs for _, _, needs in benches):
        res = _results(fast=args.fast)

    lines = ["name,value,derived"]

    def emit(line):
        lines.append(line)
        print(line)
        sys.stdout.flush()

    print(lines[0])
    errors = 0
    for bname, bench, _ in benches:
        try:
            for name, value, derived in bench(res):
                emit(f"{name},{value:.6g},{derived}")
        except Exception as e:  # noqa: BLE001
            errors += 1
            emit(f"{bname},ERROR,{type(e).__name__}: {e}")
    if args.out:
        with open(args.out, "w") as f:
            f.write("\n".join(lines) + "\n")
    if args.strict and errors:
        raise SystemExit(f"{errors} benchmark(s) errored")


if __name__ == '__main__':
    main()
