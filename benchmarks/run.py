"""Benchmark harness — one function per paper table/figure.

Prints ``name,value,derived`` CSV rows per benchmark.  Heavy artifacts
(trained experts, router, Q-tables) are produced once by
``python -m repro.core.experiment`` and re-read here; if absent, a reduced
experiment is run automatically.

  fig2      per-expert per-domain MLM accuracy (differential experts)
  fig3a     optimal-model selection accuracy vs baselines
  fig3b     domain -> expert allocation matrix fidelity
  fig3cd    per-domain aggregate accuracy, Tryage vs experts
  fig4      latent separation (silhouette scores)
  fig5      Pareto front (lambda sweep)
  router_eps  loss-prediction epsilon (paper: ~0.1)
  kernels   Pallas kernel microbenches (us/call, interpret mode)
  router_decision  router-decision throughput, fused kernel vs host path
  serving   engine throughput on batched requests
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def _results():
    from repro.core import experiment as ex
    try:
        return ex.load_results()
    except FileNotFoundError:
        print("# no cached artifacts; running reduced experiment", flush=True)
        xc = ex.ExperimentConfig(expert_steps=120, n_train_prompts=1024,
                                 n_val_prompts=192, n_test_per_domain=48,
                                 router_epochs=5)
        return ex.run_experiment(xc, verbose=False)


def bench_fig2(res):
    rows = []
    for d, accs in res["per_domain"].items():
        experts = {k: v for k, v in accs.items() if k != "tryage"}
        best = max(experts, key=experts.get)
        gen = experts.get("roberta-analog", 0.0)
        rows.append((f"fig2/{d}/best_expert", experts[best],
                     f"{best};generalist={gen:.3f}"))
    return rows


def bench_fig3a(res):
    rows = []
    for k, v in res["selection_accuracy"].items():
        rows.append((f"fig3a/selection_acc/{k.split()[0]}", v, ""))
    return rows


def bench_fig3b(res):
    from repro.data.corpus import DOMAINS
    alloc = np.array(res["allocation"])
    lib = [e["name"] for e in res["library"]]
    rows = []
    for di, d in enumerate(DOMAINS):
        mi = int(alloc[di].argmax())
        rows.append((f"fig3b/top_alloc/{d}", float(alloc[di, mi]), lib[mi]))
    return rows


def bench_fig3cd(res):
    rows = []
    for d, accs in res["per_domain"].items():
        gain = accs["tryage"] - accs.get("roberta-analog", 0.0)
        rows.append((f"fig3cd/tryage_minus_generalist/{d}", gain, ""))
    rows.append(("fig3cd/tryage_aggregate",
                 res["aggregate_accuracy"]["tryage"],
                 f"oracle={res['aggregate_accuracy']['oracle']:.3f}"))
    return rows


def bench_fig3a_mixed(res):
    """Mixed-domain prompts (the paper's motivating case) — produced by
    scripts/mixed_domain_eval.py from cached artifacts."""
    import json
    from repro.core import experiment as ex
    path = os.path.join(ex.ART_DIR, "mixed_results.json")
    with open(path) as f:
        mixed = json.load(f)
    rows = [(f"fig3a_mixed/selection_acc/{k.split()[0]}", v, "")
            for k, v in mixed["selection_accuracy"].items()]
    rows += [(f"fig3a_mixed/aggregate_acc/{k.split()[0]}", v, "")
             for k, v in mixed["aggregate_accuracy"].items()]
    return rows


def bench_fig4(res):
    return [(f"fig4/silhouette/{k}", v, "") for k, v in res["silhouette"].items()]


def bench_fig5(res):
    rows = []
    pareto = res["pareto"]["rows"]
    base = pareto[0]
    for r in pareto:
        if r["lam"] in (0.0, 1.0, 4.0, 16.0):
            rows.append((f"fig5/acc_at_lam_{r['lam']}", r["accuracy"],
                         f"size_frac={r['size_frac']:.3f}"))
    # headline: compute saved at <=5% accuracy drop
    ok = [r for r in pareto if r["accuracy"] >= base["accuracy"] - 0.05]
    best = min(ok, key=lambda r: r["mean_size"])
    rows.append(("fig5/compute_saved_at_5pct_drop",
                 1.0 - best["mean_size"] / base["mean_size"],
                 f"lam={best['lam']:.2f}"))
    return rows


def bench_router_eps(res):
    return [("router_eps/mean_abs_err", res["router_eps"], "paper~0.1")]


def bench_kernels(res):
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.router_score.kernel import router_score_fused
    from repro.kernels.mlstm_scan.ops import mlstm_chunkwise
    rows = []
    key = jax.random.PRNGKey(0)

    def timeit(fn, *args, n=3):
        fn(*args)  # compile
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(fn(*args))
        return (time.time() - t0) / n * 1e6

    q = jax.random.normal(key, (2, 256, 4, 64))
    k = jax.random.normal(key, (2, 256, 2, 64))
    us = timeit(lambda a, b: flash_attention(a, b, b, block_q=64, block_k=64),
                q, k)
    rows.append(("kernels/flash_attention_us", us, "interpret-mode 2x256x4x64"))

    emb = jax.random.normal(key, (64, 128))
    w1 = jax.random.normal(key, (128, 128)) * 0.1
    w2 = jax.random.normal(key, (128, 11)) * 0.1
    us = timeit(lambda e: router_score_fused(
        e, w1, jnp.zeros(128), w2, jnp.zeros(11),
        jnp.zeros((1, 11)), jnp.zeros((64, 1)), block_b=64), emb)
    rows.append(("kernels/router_score_us", us, "interpret-mode 64x128"))

    qm = jax.random.normal(key, (1, 128, 2, 32))
    ig = jax.random.normal(key, (1, 128, 2))
    st = {"C": jnp.zeros((1, 2, 32, 32)), "n": jnp.zeros((1, 2, 32)),
          "m": jnp.zeros((1, 2))}
    us = timeit(lambda a: mlstm_chunkwise(a, a, a, ig, ig + 3, st, chunk=32), qm)
    rows.append(("kernels/mlstm_chunkwise_us", us, "interpret-mode 1x128x2x32"))
    return rows


def bench_router_decision(res):
    """Router-decision throughput, fused Pallas path vs host reference
    path, on a 256-request mixed-flag workload (choices must agree)."""
    import jax
    from repro.core.library import ExpertSpec, ModelLibrary, _enc
    from repro.core.objective import recency_constraint, size_constraint
    from repro.core.router import RouterConfig, init_router
    from repro.models.model import count_params, init_model
    from repro.serving import Request, TryageEngine

    lib = ModelLibrary([
        ExpertSpec("small", _enc("small", 1, 32, 2, 64, 64), {}, 0.5),
        ExpertSpec("mid", _enc("mid", 1, 48, 2, 96, 64), {}, 0.5),
        ExpertSpec("big", _enc("big", 2, 64, 2, 128, 64), {}, 0.9),
    ])
    for i, e in enumerate(lib.experts):
        e.params, _ = init_model(jax.random.PRNGKey(i), e.cfg)
        e.n_params = count_params(e.params)
    rc = RouterConfig(n_models=3, vocab_size=64, num_layers=1, d_model=32,
                      num_heads=2, d_ff=64)
    rp, _ = init_router(jax.random.PRNGKey(9), rc)
    cons = [size_constraint(lib), recency_constraint(lib)]

    rng = np.random.default_rng(0)
    toks = rng.integers(4, 64, size=(256, 64)).astype(np.int32)
    flag_mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]
    reqs = [Request(uid=i, tokens=toks[i],
                    lambdas=flag_mix[i % len(flag_mix)])
            for i in range(256)]
    batches = [reqs[i:i + 32] for i in range(0, 256, 32)]

    rows, choices = [], {}
    for name, use_kernel in [("host", False), ("fused", True)]:
        eng = TryageEngine(lib, rp, rc, cons, max_batch=32,
                           use_kernel=use_kernel)
        eng._route_batch(batches[0])  # compile
        t0 = time.time()
        ch = []
        for b in batches:
            _, c = eng._route_batch(b)
            ch.append(c)
        dt = time.time() - t0
        choices[name] = np.concatenate(ch)
        rows.append((f"router_decision/{name}_req_per_s", 256 / dt,
                     "256 reqs warm, batch 32"))
    match = float((choices["host"] == choices["fused"]).mean())
    rows.append(("router_decision/choice_match", match,
                 "fused vs host, must be 1"))
    return rows


def bench_serving(res):
    from repro.core import experiment as ex
    from repro.core.objective import size_constraint, recency_constraint
    from repro.serving import Request, TryageEngine
    from repro.data.batching import mlm_batch
    art = ex.load_artifacts()
    lib, rp, rc, corpus = (art["library"], art["router_params"], art["rc"],
                           art["corpus"])
    eng = TryageEngine(lib, rp, rc,
                       [size_constraint(lib), recency_constraint(lib)],
                       max_batch=32)
    rng = np.random.default_rng(0)
    uniform = {d: 1.0 / 8 for d in corpus.tables}
    toks, _ = corpus.sample_mixture(uniform, 128, 128, rng)
    mb = mlm_batch(toks, rng, 0.15, corpus.vocab_size)
    for i in range(128):
        eng.submit(Request(uid=i, tokens=mb["tokens"][i],
                           targets=mb["targets"][i], mask=mb["mask"][i],
                           lambdas={"size": 0.5} if i % 2 else {}))
    t0 = time.time()
    results = eng.run()
    dt = time.time() - t0
    accs = [r.accuracy for r in results if r.accuracy is not None]
    return [
        ("serving/requests_per_s", len(results) / dt, "128 reqs warm"),
        ("serving/mean_accuracy", float(np.mean(accs)), ""),
        ("serving/experts_used", float(len(eng.stats.per_expert)), ""),
    ]


BENCHES = [bench_fig2, bench_fig3a, bench_fig3a_mixed, bench_fig3b, bench_fig3cd, bench_fig4,
           bench_fig5, bench_router_eps, bench_kernels,
           bench_router_decision, bench_serving]


def main() -> None:
    res = _results()
    print("name,value,derived")
    for bench in BENCHES:
        try:
            for name, value, derived in bench(res):
                print(f"{name},{value:.6g},{derived}")
        except Exception as e:  # noqa: BLE001
            print(f"{bench.__name__},ERROR,{type(e).__name__}: {e}")
        sys.stdout.flush()


if __name__ == '__main__':
    main()
