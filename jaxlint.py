"""Delegation shim so ``python -m jaxlint`` works from the repo root.

The real package lives in ``tools/jaxlint``; this module prepends
``tools`` to ``sys.path`` and re-resolves the import so the package (an
earlier path entry) wins over this file.
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent / "tools"))

if __name__ == "__main__":
    from jaxlint.cli import main
    raise SystemExit(main())
