"""Docs tree checker: every internal markdown link resolves (file and
anchor) and every ``path/to/file.py:symbol`` code pointer names a real
file containing that symbol.

  python tools/docscheck.py                 # docs/*.md + README.md
  python tools/docscheck.py docs/FOO.md     # explicit files

Stdlib-only, so the CI docs job runs it without installing the package.
Exit code is the number of broken references; each is printed as
``file:line: message``.
"""

from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
# `src/.../file.py:Symbol` or `file.py:Symbol.sub` inside backticks
POINTER_RE = re.compile(r"`([\w./-]+\.py):([A-Za-z_][\w.]*)`")


def slugify(heading: str) -> str:
    """GitHub-style heading -> anchor: lowercase, drop punctuation,
    spaces to hyphens."""
    text = re.sub(r"`([^`]*)`", r"\1", heading.strip())
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)
    text = text.lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(md: pathlib.Path) -> set[str]:
    out = set()
    in_fence = False
    for line in md.read_text().splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if m:
            out.add(slugify(m.group(1)))
    return out


def resolve_py(path_str: str, md: pathlib.Path) -> pathlib.Path | None:
    """A code pointer's file part: repo-root-relative first, then
    relative to the doc, then a unique basename match under src/."""
    for base in (REPO, md.parent):
        p = (base / path_str).resolve()
        if p.is_file():
            return p
    hits = [p for p in REPO.glob(f"src/**/{path_str}") if p.is_file()]
    return hits[0] if len(hits) == 1 else None


def symbol_in(py: pathlib.Path, symbol: str) -> bool:
    last = symbol.split(".")[-1]
    text = py.read_text()
    return re.search(
        rf"^\s*(?:def|class)\s+{re.escape(last)}\b"
        rf"|^\s*{re.escape(last)}\s*[:=]",
        text, re.MULTILINE) is not None


def check_file(md: pathlib.Path, anchor_cache: dict) -> list[str]:
    errors = []
    in_fence = False
    for lineno, line in enumerate(md.read_text().splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            dest = md if not path_part else (md.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{md}:{lineno}: broken link: {target}")
                continue
            if anchor and dest.suffix == ".md":
                if dest not in anchor_cache:
                    anchor_cache[dest] = anchors_of(dest)
                if anchor not in anchor_cache[dest]:
                    errors.append(f"{md}:{lineno}: broken anchor: "
                                  f"{target} (no heading '#{anchor}')")
        for m in POINTER_RE.finditer(line):
            path_str, symbol = m.groups()
            py = resolve_py(path_str, md)
            if py is None:
                errors.append(f"{md}:{lineno}: code pointer to missing "
                              f"file: {path_str}")
            elif not symbol_in(py, symbol):
                errors.append(f"{md}:{lineno}: symbol '{symbol}' not "
                              f"found in {path_str}")
    return errors


def main(argv: list[str]) -> int:
    if argv:
        files = [pathlib.Path(a).resolve() for a in argv]
    else:
        files = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]
    anchor_cache: dict = {}
    errors = []
    for md in files:
        if not md.is_file():
            errors.append(f"{md}: no such file")
            continue
        errors.extend(check_file(md, anchor_cache))
    for e in errors:
        print(e)
    print(f"docscheck: {len(files)} file(s), {len(errors)} problem(s)")
    return min(len(errors), 125)


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
