"""JAX rules: JXL001 (host sync), JXL002 (PRNG discipline), JXL003
(side effects under jit), JXL004 (recompilation hazards).

Each rule is ``(FileContext, ModuleIndex) -> list[Finding]``.  The rules
lean on path scoping from ``FileContext``: the hot-path half of JXL001
only fires under ``src/**/serving``, the bare-PRNGKey half of JXL002
only fires in library code (``src/**``) — tests, benchmarks and scripts
are designated entry points where a literal seed is the whole point.
"""

from __future__ import annotations

import ast

from jaxlint.core import FileContext, Finding
from jaxlint.dataflow import ModuleIndex, bound_names, endpoint, root_name

NP_ALIASES = ("np", "numpy", "onp")


def _finding(ctx: FileContext, node: ast.AST, code: str,
             message: str) -> Finding:
    return Finding(ctx.rel, node.lineno, node.col_offset, code, message)


# ----------------------------------------------------------- JXL001

def _is_host_scalar_already(arg: ast.AST) -> bool:
    """int()/float() of shapes, len() or literals is host-side already."""
    if isinstance(arg, ast.Constant):
        return True
    if isinstance(arg, ast.Subscript):
        v = arg.value
        return isinstance(v, ast.Attribute) and v.attr == "shape"
    if isinstance(arg, ast.Attribute):
        return arg.attr in ("shape", "ndim", "size")
    if isinstance(arg, ast.Call):
        return endpoint(arg.func) in ("len", "range")
    return False


def _sync_kind(node: ast.AST) -> str | None:
    """Classify a node as a host-device sync expression, if it is one."""
    if not isinstance(node, ast.Call):
        return None
    f = node.func
    if (isinstance(f, ast.Name) and f.id in ("float", "int")
            and len(node.args) == 1
            and not _is_host_scalar_already(node.args[0])):
        return f"{f.id}()"
    if isinstance(f, ast.Attribute):
        if f.attr == "item" and not node.args:
            return ".item()"
        if (f.attr in ("asarray", "array")
                and isinstance(f.value, ast.Name)
                and f.value.id in NP_ALIASES):
            return f"{f.value.id}.{f.attr}()"
    return None


def check_jxl001(ctx: FileContext, idx: ModuleIndex) -> list[Finding]:
    out: list[Finding] = []
    seen: set[tuple[int, int]] = set()
    # (a) any sync expression inside a function body that traces under jit
    for fn in idx.jit_functions:
        for node in ast.walk(fn):
            kind = _sync_kind(node)
            if kind and (node.lineno, node.col_offset) not in seen:
                seen.add((node.lineno, node.col_offset))
                out.append(_finding(
                    ctx, node, "JXL001",
                    f"{kind} forces a host-device sync inside a jit'd "
                    "function"))
    # (b) serving hot path: a blocking scalar pull directly off a jit call
    if ctx.in_hot_path:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            inner = None
            if (isinstance(f, ast.Name) and f.id in ("float", "int")
                    and len(node.args) == 1):
                inner = node.args[0]
            elif (isinstance(f, ast.Attribute) and f.attr == "item"
                  and not node.args):
                inner = f.value
            if (isinstance(inner, ast.Call) and idx.is_jit_call(inner)
                    and (node.lineno, node.col_offset) not in seen):
                seen.add((node.lineno, node.col_offset))
                out.append(_finding(
                    ctx, node, "JXL001",
                    "blocking scalar pull of a jit output in the serving "
                    "hot path"))
    return out


# ----------------------------------------------------------- JXL002

RANDOM_BASES = ("jax.random", "jrandom", "jr")
NONCONSUMING = {"split", "fold_in", "PRNGKey", "key", "key_data",
                "wrap_key_data", "clone"}


def _consumed_key(call: ast.Call) -> ast.AST | None:
    """The key expression consumed by a jax.random sampler call."""
    f = call.func
    if not isinstance(f, ast.Attribute) or f.attr in NONCONSUMING:
        return None
    try:
        base = ast.unparse(f.value)
    except Exception:
        return None
    if base not in RANDOM_BASES:
        return None
    if call.args:
        return call.args[0]
    for kw in call.keywords:
        if kw.arg == "key":
            return kw.value
    return None


class _ScopeKeys(ast.NodeVisitor):
    """Linear scan of one function/module scope for key consumption.

    Tracks, in source order: sampler calls (consumption of the key
    expression's unparsed text), assignments (invalidate entries rooted
    at the reassigned name), and loop nesting (a key rooted outside the
    loop and consumed inside it is consumed once per iteration)."""

    def __init__(self, ctx: FileContext, scope_node: ast.AST):
        self.ctx = ctx
        self.scope = scope_node
        self.used: dict[str, ast.AST] = {}
        self.loops: list[tuple[ast.AST, set[str]]] = []  # (node, bound)
        self.findings: list[Finding] = []

    # -- scope boundaries: nested functions get their own scan
    def _nested(self, node: ast.AST) -> None:
        if node is not self.scope:
            sub = _ScopeKeys(self.ctx, node)
            body = node.body if isinstance(node.body, list) else [node.body]
            for st in body:
                sub.visit(st)
            self.findings.extend(sub.findings)
        else:
            self.generic_visit(node)

    visit_FunctionDef = visit_AsyncFunctionDef = visit_Lambda = _nested

    def _loop(self, node: ast.AST) -> None:
        bound: set[str] = set()
        for n in ast.walk(node):
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
                bound.add(n.id)
        self.loops.append((node, bound))
        self.generic_visit(node)
        self.loops.pop()

    visit_For = visit_AsyncFor = visit_While = _loop

    def visit_Assign(self, node: ast.Assign) -> None:
        self.visit(node.value)
        for t in node.targets:
            for n in ast.walk(t):
                if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
                    self.used = {s: u for s, u in self.used.items()
                                 if root_name(ast.parse(s, mode="eval").body)
                                 != n.id}

    def visit_Call(self, node: ast.Call) -> None:
        self.generic_visit(node)
        key = _consumed_key(node)
        if key is None:
            return
        try:
            s = ast.unparse(key)
        except Exception:
            return
        root = root_name(key)
        if s in self.used:
            self.findings.append(_finding(
                self.ctx, node, "JXL002",
                f"PRNG key `{s}` consumed twice without jax.random.split"))
            return
        if self.loops and root is not None:
            names_in_key = {n.id for n in ast.walk(key)
                            if isinstance(n, ast.Name)}
            loop_bound = set().union(*(b for _, b in self.loops))
            if not (names_in_key & loop_bound):
                self.findings.append(_finding(
                    self.ctx, node, "JXL002",
                    f"PRNG key `{s}` rooted outside the loop is consumed "
                    "every iteration without split"))
                return
        self.used[s] = node


def check_jxl002(ctx: FileContext, idx: ModuleIndex) -> list[Finding]:
    out: list[Finding] = []
    # (a) same key expression consumed twice / consumed inside a loop
    scanner = _ScopeKeys(ctx, ctx.tree)
    for st in ctx.tree.body:
        scanner.visit(st)
    out.extend(scanner.findings)
    # (b) bare PRNGKey(<literal>) in library code
    if ctx.in_lib:
        for node in ast.walk(ctx.tree):
            if (isinstance(node, ast.Call)
                    and endpoint(node.func) == "PRNGKey"
                    and node.args
                    and isinstance(node.args[0], ast.Constant)
                    and isinstance(node.args[0].value, int)):
                out.append(_finding(
                    ctx, node, "JXL002",
                    f"bare PRNGKey({node.args[0].value}) literal in library "
                    "code"))
    return out


# ----------------------------------------------------------- JXL003

MUTATORS = {"append", "extend", "insert", "update", "add", "pop",
            "popitem", "remove", "discard", "clear", "setdefault"}


def check_jxl003(ctx: FileContext, idx: ModuleIndex) -> list[Finding]:
    out: list[Finding] = []
    seen: set[tuple[int, int]] = set()

    def emit(node: ast.AST, msg: str) -> None:
        if (node.lineno, node.col_offset) not in seen:
            seen.add((node.lineno, node.col_offset))
            out.append(_finding(ctx, node, "JXL003", msg))

    for fn in idx.jit_functions:
        local = bound_names(fn)
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Name) and f.id == "print":
                    emit(node, "print() under jax.jit runs at trace time "
                               "only")
                elif (isinstance(f, ast.Attribute) and f.attr in MUTATORS
                      and root_name(f.value) is not None
                      and root_name(f.value) not in local):
                    emit(node, f".{f.attr}() mutates closed-over/global "
                               "state under jax.jit")
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if isinstance(t, (ast.Subscript, ast.Attribute)):
                        r = root_name(t.value)
                        if r is not None and r not in local:
                            emit(node, "assignment into closed-over/global "
                                       "state under jax.jit")
            elif isinstance(node, (ast.Global, ast.Nonlocal)):
                emit(node, f"{type(node).__name__.lower()} statement under "
                           "jax.jit")
    return out


# ----------------------------------------------------------- JXL004

UNHASHABLE_ARG = (ast.List, ast.ListComp, ast.Dict, ast.DictComp,
                  ast.Set, ast.SetComp, ast.GeneratorExp, ast.Lambda)


def check_jxl004(ctx: FileContext, idx: ModuleIndex) -> list[Finding]:
    out: list[Finding] = []
    # (a) jit'd defs whose python-valued defaults are not static
    for fn, statics in idx.jit_functions.items():
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        a = fn.args
        pos = [*a.posonlyargs, *a.args]
        defaulted = (list(zip(pos[len(pos) - len(a.defaults):], a.defaults))
                     + [(arg, d) for arg, d in zip(a.kwonlyargs, a.kw_defaults)
                        if d is not None])
        for arg, default in defaulted:
            if arg.arg in statics:
                continue
            bad = (isinstance(default, (ast.List, ast.Dict, ast.Set))
                   or (isinstance(default, ast.Constant)
                       and isinstance(default.value, (bool, str))))
            if bad:
                out.append(_finding(
                    ctx, default, "JXL004",
                    f"parameter `{arg.arg}` of jit'd `{fn.name}` has a "
                    "Python-valued default but is not in static_argnames"))
    # (b) unhashable/dynamic literals handed to a jit'd call site
    statics_all = idx.all_static_names()
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call) and idx.is_jit_call(node)):
            continue
        args = list(node.args) + [kw.value for kw in node.keywords
                                  if kw.arg not in statics_all]
        for arg in args:
            if isinstance(arg, UNHASHABLE_ARG):
                out.append(_finding(
                    ctx, arg, "JXL004",
                    f"{type(arg).__name__} literal passed to jit'd "
                    f"`{endpoint(node.func)}` retraces on every call"))
    return out


JAX_RULES = (check_jxl001, check_jxl002, check_jxl003, check_jxl004)
