"""Pallas rules: PLL001 (in-kernel hazards) and PLL002 (structure).

PLL001 fires only on files under ``src/**/kernels`` and checks three
statically-visible hazard classes:

1. a ``pallas_call`` grid built with ``A // B`` in a function that never
   guards divisibility (no ``% B`` anywhere in the function — neither an
   assert nor a padding expression);
2. a ``pl.load``/``pl.store`` index tuple (or a ref subscript) mixing an
   int literal with ``pl.ds`` — the interpret-mode indexing bug class
   that PR 1 fixed by hand (leading axes must use ``pl.ds(i, 1)``);
3. a function that launches ``pallas_call`` without routing its backend
   choice through ``kernels.default_interpret``.

PLL002 is a structural pass over the whole scanned set: every
``kernels/*/kernel.py`` must have a sibling ``ref.py`` and a parity test
under the tests dir that references the package and its ref.
"""

from __future__ import annotations

import ast
import pathlib

from jaxlint.core import FileContext, Finding
from jaxlint.dataflow import ModuleIndex, endpoint


def _finding(ctx: FileContext, node: ast.AST, code: str,
             message: str) -> Finding:
    return Finding(ctx.rel, node.lineno, node.col_offset, code, message)


def _is_pl_ds(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call) and endpoint(node.func) == "ds")


def _functions(tree: ast.Module):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _floordiv_divisors(expr: ast.AST) -> list[str]:
    out = []
    for node in ast.walk(expr):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.FloorDiv):
            try:
                out.append(ast.unparse(node.right))
            except Exception:
                pass
    return out


def _local_assignment(fn: ast.AST, name: str) -> ast.AST | None:
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    return node.value
    return None


def check_pll001(ctx: FileContext, idx: ModuleIndex) -> list[Finding]:
    if not ctx.in_kernels:
        return []
    out: list[Finding] = []
    for fn in _functions(ctx.tree):
        mods = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mod):
                try:
                    mods.add(ast.unparse(node.right))
                except Exception:
                    pass
        calls_default_interpret = any(
            isinstance(n, ast.Call)
            and endpoint(n.func) == "default_interpret"
            for n in ast.walk(fn))
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if endpoint(node.func) == "pallas_call":
                # (1) grid divisibility
                grid = next((kw.value for kw in node.keywords
                             if kw.arg == "grid"), None)
                if grid is not None:
                    if isinstance(grid, ast.Name):
                        grid = _local_assignment(fn, grid.id) or grid
                    for div in _floordiv_divisors(grid):
                        if div not in mods:
                            out.append(_finding(
                                ctx, node, "PLL001",
                                f"grid uses `// {div}` but the function "
                                f"never guards `% {div}` (assert or pad)"))
                # (3) interpret routing
                if not calls_default_interpret:
                    out.append(_finding(
                        ctx, node, "PLL001",
                        "pallas_call launched without routing interpret "
                        "through kernels.default_interpret"))
            elif (endpoint(node.func) in ("load", "store")
                  and len(node.args) >= 2
                  and isinstance(node.args[1], ast.Tuple)):
                elts = node.args[1].elts
                has_int = any(isinstance(e, ast.Constant)
                              and isinstance(e.value, int) for e in elts)
                if has_int and any(_is_pl_ds(e) for e in elts):
                    out.append(_finding(
                        ctx, node, "PLL001",
                        "index tuple mixes an int literal with pl.ds — "
                        "use pl.ds(i, 1) for the leading axis"))
        # (2b) ref subscripts mixing int literals with pl.ds
        for node in ast.walk(fn):
            if (isinstance(node, ast.Subscript)
                    and isinstance(node.slice, ast.Tuple)):
                elts = node.slice.elts
                has_int = any(isinstance(e, ast.Constant)
                              and isinstance(e.value, int) for e in elts)
                if has_int and any(_is_pl_ds(e) for e in elts):
                    out.append(_finding(
                        ctx, node, "PLL001",
                        "subscript mixes an int literal with pl.ds — "
                        "use pl.ds(i, 1) for the leading axis"))
    return out


PALLAS_RULES = (check_pll001,)


# ----------------------------------------------------------- PLL002

def structural_pass(contexts: list[FileContext],
                    tests_dir: str = "tests") -> list[Finding]:
    """Every scanned kernels/*/kernel.py needs a ref.py and a parity
    test mentioning both the package name and its ref."""
    out: list[Finding] = []
    tests_root = pathlib.Path(tests_dir)
    test_texts: list[str] = []
    if tests_root.is_dir():
        for f in sorted(tests_root.rglob("*.py")):
            try:
                test_texts.append(f.read_text())
            except OSError:
                pass
    for ctx in contexts:
        if not (ctx.in_kernels and ctx.parts[-1] == "kernel.py"):
            continue
        pkg = ctx.path.parent.name
        if not (ctx.path.parent / "ref.py").is_file():
            out.append(Finding(
                ctx.rel, 1, 0, "PLL002",
                f"kernel package `{pkg}` has no sibling ref.py reference "
                "implementation"))
        if not any(pkg in t and "ref" in t for t in test_texts):
            out.append(Finding(
                ctx.rel, 1, 0, "PLL002",
                f"no test under {tests_dir}/ checks `{pkg}` against its "
                "ref"))
    return out
