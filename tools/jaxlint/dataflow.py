"""Module-level jit/dataflow indexing shared by the JAX rules.

``ModuleIndex`` answers two questions the rules keep asking:

* which function bodies trace under ``jax.jit`` — decorator forms
  (``@jax.jit``, ``@functools.partial(jax.jit, static_argnames=...)``),
  call forms (``jax.jit(fn)``, ``jax.jit(lambda ...)``,
  ``jax.jit(functools.partial(self.method, ...))``), in any of which the
  referenced def's body is traced;
* which *call sites* invoke a jit'd callable — a name or attribute that
  was assigned from a ``jax.jit(...)`` expression (``f = jax.jit(...)``,
  ``self._decide = jax.jit(...)``), or a def decorated with jit.

Everything is a static heuristic over one module: no imports are
resolved, so a jit callable passed across modules is invisible.  That is
the deliberate trade — zero false positives from aliasing beat
exhaustive recall for a lint gate.
"""

from __future__ import annotations

import ast

FunctionNode = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def endpoint(node: ast.AST) -> str | None:
    """Rightmost identifier of a Name/Attribute chain, else None."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def root_name(node: ast.AST) -> str | None:
    """Leftmost Name of an attribute/subscript/call chain, else None."""
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Call)):
        node = node.func if isinstance(node, ast.Call) else node.value
    return node.id if isinstance(node, ast.Name) else None


def is_jax_jit(node: ast.AST) -> bool:
    if isinstance(node, ast.Attribute) and node.attr == "jit":
        return endpoint(node.value) == "jax"
    return isinstance(node, ast.Name) and node.id == "jit"


def _is_partial(node: ast.AST) -> bool:
    return endpoint(node) == "partial"


def static_names(call: ast.Call) -> set[str]:
    """static_argnames declared on a jit/partial call (str or tuple)."""
    out: set[str] = set()
    for kw in call.keywords:
        if kw.arg != "static_argnames":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, str):
            out.add(v.value)
        elif isinstance(v, (ast.Tuple, ast.List)):
            out.update(e.value for e in v.elts
                       if isinstance(e, ast.Constant)
                       and isinstance(e.value, str))
    return out


def bound_names(fn: ast.AST) -> set[str]:
    """Names bound inside a function: params + every store target."""
    out: set[str] = set()
    if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
        a = fn.args
        for arg in (*a.posonlyargs, *a.args, *a.kwonlyargs):
            out.add(arg.arg)
        if a.vararg:
            out.add(a.vararg.arg)
        if a.kwarg:
            out.add(a.kwarg.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            out.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)) and node is not fn:
            out.add(node.name)
        elif isinstance(node, ast.ExceptHandler) and node.name:
            out.add(node.name)
    return out


class ModuleIndex:
    """Jit view of one module (see module docstring)."""

    def __init__(self, tree: ast.Module):
        self.tree = tree
        #: function node -> static_argnames declared for it
        self.jit_functions: dict[ast.AST, set[str]] = {}
        #: bare names whose call sites are jit'd (jit-decorated defs and
        #: ``f = jax.jit(...)`` locals)
        self.jit_names: set[str] = set()
        #: attribute names assigned ``<obj>.<attr> = jax.jit(...)``
        self.jit_attr_names: set[str] = set()
        self._defs: dict[str, list[ast.AST]] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._defs.setdefault(node.name, []).append(node)
        self._scan_decorators()
        self._scan_jit_calls()
        self._scan_assignments()

    # ------------------------------------------------------------------
    def _mark(self, fn: ast.AST, statics: set[str]) -> None:
        self.jit_functions.setdefault(fn, set()).update(statics)

    def _scan_decorators(self) -> None:
        for defs in self._defs.values():
            for fn in defs:
                for dec in fn.decorator_list:
                    if is_jax_jit(dec):
                        self._mark(fn, set())
                        self.jit_names.add(fn.name)
                    elif isinstance(dec, ast.Call):
                        if is_jax_jit(dec.func):
                            self._mark(fn, static_names(dec))
                            self.jit_names.add(fn.name)
                        elif (_is_partial(dec.func) and dec.args
                              and is_jax_jit(dec.args[0])):
                            self._mark(fn, static_names(dec))
                            self.jit_names.add(fn.name)

    def _scan_jit_calls(self) -> None:
        for node in ast.walk(self.tree):
            if not (isinstance(node, ast.Call) and is_jax_jit(node.func)
                    and node.args):
                continue
            statics = static_names(node)
            target = node.args[0]
            if isinstance(target, ast.Lambda):
                self._mark(target, statics)
                continue
            if (isinstance(target, ast.Call) and _is_partial(target.func)
                    and target.args):
                target = target.args[0]
            name = endpoint(target)
            # the *def body* traces under jit; its bare name stays unjit'd
            # (callers go through the jit'd alias, e.g. self._decide)
            for fn in self._defs.get(name or "", []):
                self._mark(fn, statics)

    def _scan_assignments(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets, value = [node.target], node.value
            else:
                continue
            if not (isinstance(value, ast.Call) and is_jax_jit(value.func)):
                continue
            for t in targets:
                if isinstance(t, ast.Name):
                    self.jit_names.add(t.id)
                elif isinstance(t, ast.Attribute):
                    self.jit_attr_names.add(t.attr)

    # ------------------------------------------------------------------
    def is_jit_call(self, call: ast.Call) -> bool:
        """Does this call site invoke a known jit'd callable?"""
        f = call.func
        if isinstance(f, ast.Name):
            return f.id in self.jit_names
        if isinstance(f, ast.Attribute):
            return f.attr in self.jit_attr_names
        return False

    def all_static_names(self) -> set[str]:
        out: set[str] = set()
        for statics in self.jit_functions.values():
            out |= statics
        return out
