"""Fixture: PRNG key reuse (JXL002a)."""

import jax


def double_draw(key):
    a = jax.random.normal(key, (4,))
    b = jax.random.normal(key, (4,))   # JXL002: same key consumed twice
    return a + b


def loop_draw(key, n):
    total = 0.0
    for _ in range(n):
        total += jax.random.uniform(key)   # JXL002: key reused per iteration
    return total


def clean(key):
    k1, k2 = jax.random.split(key)
    return jax.random.normal(k1, (4,)) + jax.random.normal(k2, (4,))


def clean_loop(key, n):
    ks = jax.random.split(key, n)
    return sum(jax.random.uniform(ks[i]) for i in range(n))
