"""Fixture: host-device syncs inside jit'd functions (JXL001)."""

import functools

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def decorated_sync(x):
    s = float(jnp.sum(x))          # JXL001: float() under jit
    return x * s


@functools.partial(jax.jit, static_argnames=("k",))
def partial_sync(x, k=2):
    m = jnp.max(x).item()          # JXL001: .item() under jit
    host = np.asarray(x)           # JXL001: np.asarray under jit
    return x * m + host.shape[0] * k


def _body(x):
    return int(jnp.argmax(x))      # JXL001: int() under jit via jax.jit(_body)


scorer = jax.jit(_body)


@jax.jit
def clean(x):
    n = int(x.shape[0])            # shapes are host ints — not flagged
    return x / n
