"""Fixture: a Pallas kernel violating every PLL001 sub-check (and
PLL002 — no sibling ref.py, no parity test)."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _body(x_ref, o_ref):
    i = pl.program_id(0)
    # PLL001: int literal mixed with pl.ds in the index tuple
    row = pl.load(x_ref, (0, pl.ds(i * 8, 8)))
    o_ref[0, pl.ds(i * 8, 8)] = row * 2.0


@jax.jit
def double_rows(x, block=8):
    n = x.shape[1]
    # PLL001: grid divides by `block` but nothing guards n % block;
    # PLL001: interpret never routed through kernels.default_interpret
    return pl.pallas_call(
        _body,
        grid=(n // block,),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x)
