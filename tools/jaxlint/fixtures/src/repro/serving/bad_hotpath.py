"""Fixture: hot-path scalar pulls and bare PRNGKey in library code.

Lives under a ``src/repro/serving`` subtree so the path-scoped halves of
JXL001 (serving hot path) and JXL002 (library code) fire.
"""

import jax


class MiniEngine:
    def __init__(self, rc):
        self._pred_err = jax.jit(lambda p, t: (p * t).sum())
        self.key = jax.random.PRNGKey(0)   # JXL002: bare literal in library

    def step(self, params, toks):
        # JXL001 x2: blocking scalar pull per call in the hot path
        pre = float(self._pred_err(params, toks))
        post = float(self._pred_err(params, toks + 1))
        return pre, post
