"""Fixture: Python side effects under jax.jit (JXL003)."""

import jax
import jax.numpy as jnp

TRACE_LOG = []
STATE = {"count": 0}


@jax.jit
def noisy(x):
    print("tracing", x.shape)        # JXL003: print under jit
    TRACE_LOG.append(x.shape)        # JXL003: closed-over list mutation
    STATE["count"] = 1               # JXL003: closed-over dict mutation
    return jnp.tanh(x)


@jax.jit
def clean(x):
    scales = []
    scales.append(2.0)               # local list — not flagged
    return x * scales[0]
