"""Fixture: recompilation hazards at jit boundaries (JXL004)."""

import functools

import jax
import jax.numpy as jnp


@jax.jit
def branchy(x, causal=True, mode="fast"):   # JXL004 x2: non-static defaults
    if causal:
        x = jnp.tril(x)
    return x if mode == "fast" else x * 2


@functools.partial(jax.jit, static_argnames=("causal", "mode"))
def branchy_ok(x, causal=True, mode="fast"):    # statics declared — clean
    if causal:
        x = jnp.tril(x)
    return x if mode == "fast" else x * 2


step = jax.jit(lambda p, b: p + b["x"])


def run(p):
    return step(p, {"x": jnp.ones(3)})   # JXL004: dict literal to jit call
