"""jaxlint — AST-based static analysis for JAX/Pallas hazards.

The serving stack routes every request through jit boundaries and Pallas
kernels; the hazards this tool hunts (host-device syncs, PRNG key reuse,
impure jit bodies, recompilation traps, BlockSpec/grid mismatches) are
silent at runtime until they cost throughput or correctness.  Run it as

    python -m jaxlint src tests benchmarks

from the repo root (a delegation shim lives at the root; the package
itself is importable with ``tools`` on ``sys.path``).  Suppress a single
finding with an inline ``# jaxlint: disable=<CODE>`` comment on the
flagged line.
"""

from jaxlint.core import Finding, RULES, analyze_paths

__version__ = "0.1.0"
__all__ = ["Finding", "RULES", "analyze_paths", "__version__"]
