"""jaxlint CLI: ``python -m jaxlint [paths ...]``.

Exit codes: 0 clean, 1 findings, 2 parse/usage errors.  Suppressed
findings never affect the exit code but are printed and counted in the
JSON report (``--report``), so CI can hold the suppression budget.
"""

from __future__ import annotations

import argparse

from jaxlint.core import analyze_paths
from jaxlint.report import render_rules, render_text, write_json


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="jaxlint",
        description="AST-based static analysis for JAX/Pallas hazards")
    ap.add_argument("paths", nargs="*", default=["src", "tests",
                                                 "benchmarks"],
                    help="files or directories to scan (default: "
                         "src tests benchmarks)")
    ap.add_argument("--report", metavar="FILE",
                    help="write a JSON report (CI artifact)")
    ap.add_argument("--tests-dir", default="tests",
                    help="where PLL002 looks for parity tests")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(render_rules())
        return 0

    active, suppressed, errors, n_files = analyze_paths(
        args.paths, tests_dir=args.tests_dir)
    print(render_text(active, suppressed, errors, n_files))
    if args.report:
        write_json(args.report, active, suppressed, errors, n_files)
    if errors:
        return 2
    return 1 if active else 0
