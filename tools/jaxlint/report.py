"""Rendering: human-readable text and the JSON artifact CI uploads."""

from __future__ import annotations

import collections
import json
import pathlib

from jaxlint.core import RULES, Finding


def render_text(active: list[Finding], suppressed: list[Finding],
                errors: list[str], n_files: int) -> str:
    lines = [f.format() for f in active]
    for f in suppressed:
        lines.append(f"{f.path}:{f.line}:{f.col}: {f.code} suppressed: "
                     f"{f.message}")
    for e in errors:
        lines.append(f"error: {e}")
    counts = collections.Counter(f.code for f in active)
    by_code = ", ".join(f"{c}={n}" for c, n in sorted(counts.items()))
    tail = (f"jaxlint: {len(active)} finding(s)"
            + (f" [{by_code}]" if by_code else "")
            + f", {len(suppressed)} suppressed, {len(errors)} parse "
              f"error(s), {n_files} file(s) scanned")
    lines.append(tail)
    return "\n".join(lines)


def render_rules() -> str:
    lines = ["jaxlint rules:"]
    for code, (desc, hint) in RULES.items():
        lines.append(f"  {code}  {desc}")
        lines.append(f"          fix: {hint}")
    return "\n".join(lines)


def _as_dict(f: Finding) -> dict:
    return {"path": f.path, "line": f.line, "col": f.col, "code": f.code,
            "message": f.message, "hint": f.hint}


def write_json(path: str, active: list[Finding], suppressed: list[Finding],
               errors: list[str], n_files: int) -> None:
    counts = collections.Counter(f.code for f in active)
    payload = {
        "findings": [_as_dict(f) for f in active],
        "suppressed": [_as_dict(f) for f in suppressed],
        "errors": errors,
        "counts": dict(sorted(counts.items())),
        "files_scanned": n_files,
        "rules": {c: {"description": d, "hint": h}
                  for c, (d, h) in RULES.items()},
    }
    pathlib.Path(path).write_text(json.dumps(payload, indent=2) + "\n")
