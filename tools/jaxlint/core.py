"""Analyzer infrastructure: findings, rule registry, suppressions, driving.

A *rule* is a callable ``(FileContext, ModuleIndex) -> list[Finding]``
registered in ``RULES`` with a one-line description and fix hint.  The
driver parses each file once, builds one ``ModuleIndex`` (the shared
jit/dataflow view in ``dataflow.py``), runs every per-file rule, then
runs the structural pass (PLL002) over the whole scanned set.

Suppression is line-scoped: a ``# jaxlint: disable=CODE[,CODE]`` comment
on the flagged line silences matching findings (``disable=all`` silences
every code).  Suppressed findings are still counted and reported so CI
can enforce a budget.
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib
import re

SUPPRESS_RE = re.compile(r"#\s*jaxlint:\s*disable=([A-Za-z0-9_,\s]+)")

#: code -> (one-line description, one-line fix hint)
RULES: dict[str, tuple[str, str]] = {
    "JXL001": (
        "host-device sync inside a jit'd function or the serving hot path",
        "keep values on device; batch device->host pulls into one "
        "np.asarray outside jit",
    ),
    "JXL002": (
        "PRNG key reuse, or bare PRNGKey literal in library code",
        "jax.random.split before each consumption; mint seeds via "
        "repro.core.rngs.seeded_key",
    ),
    "JXL003": (
        "Python side effect under jax.jit",
        "jit'd code must be pure: return values instead of printing or "
        "mutating closed-over state",
    ),
    "JXL004": (
        "recompilation hazard: dynamic/unhashable Python argument to a "
        "jit'd callable",
        "declare the argument in static_argnames or pass device arrays",
    ),
    "PLL001": (
        "Pallas kernel hazard: unguarded grid division, int literal mixed "
        "with pl.ds, or interpret not routed through default_interpret",
        "guard grid divisors with an assert or padding, index leading axes "
        "with pl.ds(i, 1), call kernels.default_interpret(interpret)",
    ),
    "PLL002": (
        "kernel package missing its ref.py or a parity test",
        "every kernels/*/kernel.py ships a sibling ref.py and a test that "
        "checks the kernel against it",
    ),
}


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    code: str
    message: str

    @property
    def hint(self) -> str:
        return RULES[self.code][1]

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.code} "
                f"{self.message} [hint: {self.hint}]")


class FileContext:
    """One parsed source file plus its path scopes and suppressions."""

    def __init__(self, path: pathlib.Path, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.tree = ast.parse(source, filename=rel)
        self.parts = tuple(pathlib.PurePosixPath(rel.replace("\\", "/")).parts)
        # line -> set of suppressed codes (or {"ALL"})
        self.suppressions: dict[int, set[str]] = {}
        for i, text in enumerate(source.splitlines(), 1):
            m = SUPPRESS_RE.search(text)
            if m:
                self.suppressions[i] = {
                    c.strip().upper()
                    for c in m.group(1).split(",") if c.strip()
                }

    # path scopes ------------------------------------------------------
    @property
    def in_lib(self) -> bool:
        """Library code: anything under a ``src`` directory."""
        return "src" in self.parts[:-1]

    @property
    def in_hot_path(self) -> bool:
        """The serving hot path: src/**/serving/*."""
        return self.in_lib and "serving" in self.parts[:-1]

    @property
    def in_kernels(self) -> bool:
        """Pallas kernel packages: src/**/kernels/*."""
        return self.in_lib and "kernels" in self.parts[:-1]

    def suppressed(self, finding: Finding) -> bool:
        codes = self.suppressions.get(finding.line)
        return bool(codes) and (finding.code in codes or "ALL" in codes)


def iter_py_files(roots: list[str]) -> list[pathlib.Path]:
    seen: set[pathlib.Path] = set()
    for root in roots:
        p = pathlib.Path(root)
        if p.is_file() and p.suffix == ".py":
            seen.add(p.resolve())
        elif p.is_dir():
            seen.update(f.resolve() for f in p.rglob("*.py"))
    return sorted(seen)


def analyze_paths(roots: list[str], tests_dir: str = "tests"):
    """Run every rule over ``roots``.

    Returns ``(active, suppressed, errors, n_files)`` where ``errors``
    are files that failed to parse (reported, never silently skipped).
    """
    from jaxlint.dataflow import ModuleIndex
    from jaxlint.rules_jax import JAX_RULES
    from jaxlint.rules_pallas import PALLAS_RULES, structural_pass

    cwd = pathlib.Path.cwd().resolve()
    active: list[Finding] = []
    suppressed: list[Finding] = []
    errors: list[str] = []
    contexts: list[FileContext] = []
    for path in iter_py_files(roots):
        try:
            rel = str(path.relative_to(cwd))
        except ValueError:
            rel = str(path)
        try:
            source = path.read_text()
            ctx = FileContext(path, rel, source)
        except (SyntaxError, UnicodeDecodeError, OSError) as e:
            errors.append(f"{rel}: {type(e).__name__}: {e}")
            continue
        contexts.append(ctx)
        idx = ModuleIndex(ctx.tree)
        findings: list[Finding] = []
        for rule in (*JAX_RULES, *PALLAS_RULES):
            findings.extend(rule(ctx, idx))
        for f in findings:
            (suppressed if ctx.suppressed(f) else active).append(f)
    active.extend(structural_pass(contexts, tests_dir))
    key = lambda f: (f.path, f.line, f.col, f.code)  # noqa: E731
    return (sorted(set(active), key=key), sorted(set(suppressed), key=key),
            errors, len(contexts))
