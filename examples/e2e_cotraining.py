"""Router + expert co-training (paper eq. 4/5): the routed system's loss
approaches the oracle as experts specialize on the prompts the router
sends them (self-organizing-map flavor)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core.e2e import cotrain
from repro.core.library import ExpertSpec, ModelLibrary, _enc, _mix
from repro.core.router import RouterConfig, init_router
from repro.core.training import train_library
from repro.data.corpus import DOMAINS, DomainCorpus

corpus = DomainCorpus(vocab_size=512, seed=0)
uniform = {d: 1.0 / len(DOMAINS) for d in DOMAINS}

# start from lightly-trained experts; co-training will differentiate them
library = ModelLibrary([
    ExpertSpec("expert-a", _enc("expert-a", 3, 128, 4, 512, 512), uniform),
    ExpertSpec("expert-b", _enc("expert-b", 3, 128, 4, 512, 512),
               _mix("github", "dm_math", w=0.5)),
    ExpertSpec("expert-c", _enc("expert-c", 3, 128, 4, 512, 512),
               _mix("uspto", "pubmed", w=0.5)),
])
print("warm-starting experts (60 steps each) ...")
train_library(library, corpus, steps=60, verbose=True)

rc = RouterConfig(n_models=3, vocab_size=512, num_layers=2, d_model=96)
rp, _ = init_router(jax.random.PRNGKey(0), rc)

print("co-training router + experts (eq. 4/5) ...")
state = cotrain(library, rp, rc, corpus, steps=40, verbose=True)

h0, h1 = state.history[0], state.history[-1]
print(f"\nrouted loss:  {h0['routed_loss']:.3f} -> {h1['routed_loss']:.3f}")
print(f"oracle loss:  {h0['oracle_loss']:.3f} -> {h1['oracle_loss']:.3f}")
print(f"router fit:   {h0['router_loss']:.4f} -> {h1['router_loss']:.4f}")
