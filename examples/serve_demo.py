"""End-to-end serving-front-end demo: concurrent client sessions with
user flags multiplexed through the bounded admission queue into the
TryageEngine, with a mid-stream expert failure the health-fallback chain
routes around, and a Prometheus metrics dump at the end.

  PYTHONPATH=src python examples/serve_demo.py          # cached artifacts
  PYTHONPATH=src python examples/serve_demo.py --demo   # tiny untrained
                                                        # library, seconds

The default path reuses cached experiment artifacts when present
(otherwise it trains a reduced library first, ~minutes); --demo builds a
three-expert untrained library so the full front-end flow — sessions,
load-shedding, failure injection, fallback, metrics — runs in seconds
with no artifacts.  Accuracy numbers are only meaningful on the
artifact path.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.serving import (ExpertHealth, Request, ServingFrontend, Session,
                           TryageEngine, parse_flags)
from repro.serving.metrics import render

ap = argparse.ArgumentParser()
ap.add_argument("--demo", action="store_true",
                help="tiny untrained library instead of cached artifacts "
                     "(fast, no training)")
ap.add_argument("--requests", type=int, default=96)
ap.add_argument("--sessions", type=int, default=4)
ap.add_argument("--admission-cap", type=int, default=64)
ap.add_argument("--metrics-out", type=str, default="")
args = ap.parse_args()

if args.demo:
    import jax

    from repro.core.library import ExpertSpec, ModelLibrary, _enc
    from repro.core.router import RouterConfig, init_router
    from repro.models.model import count_params, init_model

    lib = ModelLibrary([
        ExpertSpec("small", _enc("small", 1, 32, 2, 64, 64), {}, 0.5),
        ExpertSpec("mid", _enc("mid", 1, 48, 2, 96, 64), {}, 0.5),
        ExpertSpec("big", _enc("big", 2, 64, 2, 128, 64), {}, 0.9),
    ])
    for i, e in enumerate(lib.experts):
        e.params, _ = init_model(jax.random.PRNGKey(i), e.cfg)
        e.n_params = count_params(e.params)
    rc = RouterConfig(n_models=3, vocab_size=64, num_layers=1, d_model=32,
                      num_heads=2, d_ff=64)
    rp, _ = init_router(jax.random.PRNGKey(9), rc)
    rng = np.random.default_rng(0)
    tokens = rng.integers(4, 64, size=(args.requests, 64)).astype(np.int32)
    targets = mask = [None] * args.requests
else:
    from repro.core import experiment as ex
    from repro.data.batching import mlm_batch

    try:
        art = ex.load_artifacts()
    except FileNotFoundError:
        print("training reduced library first ...")
        xc = ex.ExperimentConfig(expert_steps=60, n_train_prompts=512,
                                 n_val_prompts=128, n_test_per_domain=24,
                                 router_epochs=3)
        ex.run_experiment(xc, verbose=True)
        art = ex.load_artifacts()
    lib, rp, rc, corpus = (art["library"], art["router_params"], art["rc"],
                           art["corpus"])
    rng = np.random.default_rng(0)
    uniform = {d: 1.0 / 8 for d in corpus.tables}
    toks, _ = corpus.sample_mixture(uniform, args.requests, 128, rng)
    mb = mlm_batch(toks, rng, 0.15, corpus.vocab_size)
    tokens, targets, mask = mb["tokens"], mb["targets"], mb["mask"]

from repro.core.objective import recency_constraint, size_constraint

# the health tracker + fallback_max_depth turn on the fallback chain:
# when an expert goes unhealthy, the Route stage re-scores the same
# constrained objective with that expert masked out
health = ExpertHealth(len(lib))
engine = TryageEngine(lib, rp, rc,
                      [size_constraint(lib), recency_constraint(lib)],
                      max_batch=32, buckets=True, max_wait_s=0.02,
                      health=health, fallback_max_depth=2)

# flags arrive as natural-language markers, exactly as in the paper
print("flag parsing:", parse_flags("what is X [Flag: Smallest model]"))

flags = ["", "[Flag: Small model]", "[Flag: Smallest model]"]
reqs = [Request(uid=i, tokens=tokens[i], targets=targets[i], mask=mask[i],
                lambdas=parse_flags(flags[i % 3]), priority=i % 2)
        for i in range(args.requests)]

# concurrent sessions: the frontend polls them round-robin through the
# bounded admission queue; a mid-stream failure injection on whichever
# expert serves session 0's first flush exercises the fallback chain
fail_state = {"armed": False}


def session_stream(chunk, inject_after=None):
    for k, r in enumerate(chunk):
        if inject_after is not None and k == inject_after \
                and not fail_state["armed"]:
            fail_state["armed"] = True
            busiest = int(np.argmax(engine.scheduler.depths()))
            print(f"injecting persistent failure on expert "
                  f"'{lib.experts[busiest].name}'")
            engine.scheduler.inject_failures(busiest)
        yield r


chunks = [reqs[i::args.sessions] for i in range(args.sessions)]
sessions = [Session(f"client-{i}",
                    session_stream(c, inject_after=4 if i == 0 else None))
            for i, c in enumerate(chunks)]
frontend = ServingFrontend(engine, sessions, capacity=args.admission_cap)

results = list(frontend.serve())
accs = [r.accuracy for r in results if r.accuracy is not None]
print(f"served {len(results)} requests from {args.sessions} sessions "
      f"(admitted {engine.stats.admitted}, shed {engine.stats.shed})")
if accs:
    print(f"mean masked-token accuracy {np.mean(accs):.3f}")
print("allocation:", dict(engine.stats.per_expert))
print("fallbacks:", engine.stats.fallbacks,
      "reroutes:", engine.stats.reroutes,
      "degraded:", engine.stats.degraded,
      "failed:", engine.stats.failed)
print("expert health:", health.snapshot())

names = [e.name for e in lib.experts]
text = render(engine.stats, health, names)
if args.metrics_out:
    with open(args.metrics_out, "w") as f:
        f.write(text)
    print(f"metrics written to {args.metrics_out}")
else:
    print("--- metrics (first 20 lines) ---")
    print("\n".join(text.splitlines()[:20]))
