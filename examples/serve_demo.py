"""End-to-end serving driver: batched requests with user flags through the
TryageEngine (the paper's deployment scenario).

Reuses cached experiment artifacts when present; otherwise trains a reduced
library first.  Shows flag parsing ("[Flag: Smallest model]") feeding the
constraint weights of the routing objective.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import experiment as ex
from repro.core.objective import recency_constraint, size_constraint
from repro.data.batching import mlm_batch
from repro.serving import Request, TryageEngine, parse_flags

try:
    art = ex.load_artifacts()
except FileNotFoundError:
    print("training reduced library first ...")
    xc = ex.ExperimentConfig(expert_steps=60, n_train_prompts=512,
                             n_val_prompts=128, n_test_per_domain=24,
                             router_epochs=3)
    ex.run_experiment(xc, verbose=True)
    art = ex.load_artifacts()

lib, rp, rc, corpus = (art["library"], art["router_params"], art["rc"],
                       art["corpus"])
# use_kernel=True: head -> softplus -> constraint add -> argmin run fused
# in the Pallas kernel (embedding stays in XLA, all inside one jit);
# buckets=True pads expert micro-batches to power-of-two shapes so jit
# compiles a bounded shape set.
engine = TryageEngine(lib, rp, rc,
                      [size_constraint(lib), recency_constraint(lib)],
                      max_batch=32, use_kernel=True, buckets=True)

# flags arrive as natural-language markers, exactly as in the paper
print("flag parsing:", parse_flags("what is X [Flag: Smallest model]"))

rng = np.random.default_rng(0)
uniform = {d: 1.0 / 8 for d in corpus.tables}
toks, _ = corpus.sample_mixture(uniform, 96, 128, rng)
mb = mlm_batch(toks, rng, 0.15, corpus.vocab_size)
flags = ["", "[Flag: Small model]", "[Flag: Smallest model]"]
for i in range(96):
    engine.submit(Request(uid=i, tokens=mb["tokens"][i],
                          targets=mb["targets"][i], mask=mb["mask"][i],
                          lambdas=parse_flags(flags[i % 3])))

results = engine.run()
accs = [r.accuracy for r in results if r.accuracy is not None]
losses = [r.loss for r in results if r.loss is not None]
print(f"served {len(results)} requests, mean masked-token accuracy "
      f"{np.mean(accs):.3f}, mean masked NLL {np.mean(losses):.3f}")
print("allocation:", dict(engine.stats.per_expert))
print("buckets:", dict(engine.stats.bucket_hits),
      "padded rows:", engine.stats.padded_rows)
print("total FLOPs proxy:", f"{engine.stats.total_flops:.3g}")
