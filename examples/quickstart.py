"""Quickstart: build a tiny Tryage system end-to-end in ~2 minutes on CPU.

Trains 3 small experts on different synthetic domains, builds a Q-table,
trains a perceptive router, and routes a few prompts — showing the routing
objective with and without a size-penalty flag.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.library import ExpertSpec, ModelLibrary, _enc, _mix
from repro.core.objective import route, size_constraint
from repro.core.qtable import build_q_table
from repro.core.router import RouterConfig, init_router, predict_losses
from repro.core.training import train_library, train_router
from repro.core.experiment import _eval_batches
from repro.data.corpus import DOMAINS, DomainCorpus

corpus = DomainCorpus(vocab_size=512, seed=0)
uniform = {d: 1.0 / len(DOMAINS) for d in DOMAINS}

library = ModelLibrary([
    ExpertSpec("generalist", _enc("generalist", 4, 192, 4, 768, 512), uniform),
    ExpertSpec("code-expert", _enc("code-expert", 3, 128, 4, 512, 512),
               _mix("github", "stackexchange")),
    ExpertSpec("patent-expert", _enc("patent-expert", 3, 128, 4, 512, 512),
               _mix("uspto", "freelaw")),
])

print("1. training 3 experts ...")
train_library(library, corpus, steps=150, verbose=True)

print("2. building Q-table ...")
train_b = _eval_batches(corpus, uniform, 512, 128, 1)
val_b = _eval_batches(corpus, uniform, 128, 128, 2)
q_train = build_q_table(library, train_b, progress=True)
q_val = build_q_table(library, val_b)

print("3. training router (eq. 2/3) ...")
rc = RouterConfig(n_models=3, vocab_size=512, num_layers=2, d_model=96)
rp, _ = init_router(jax.random.PRNGKey(0), rc)
cat = lambda bs: np.concatenate([b["tokens"] for b in bs])
rp, log = train_router(
    rp, rc, {"tokens": cat(train_b), "loss": q_train["loss"]},
    {"tokens": cat(val_b), "loss": q_val["loss"]}, epochs=6, verbose=True)

print("4. routing prompts (eq. 4) ...")
rng = np.random.default_rng(3)
for domain in ("github", "uspto", "books"):
    toks = corpus.sample_tokens(domain, 4, 128, rng)
    pred = predict_losses(rp, rc, {"tokens": toks})
    plain = np.asarray(route(pred))
    constrained = np.asarray(route(pred, [size_constraint(library)], [4.0]))
    names = library.names
    print(f"  {domain:12s} -> {[names[i] for i in plain]}"
          f"   [Flag: small] -> {[names[i] for i in constrained]}")
print("done.")
