"""Reproduce the paper's Fig. 5: sweep the size-penalty weight lambda and
print the accuracy / compute Pareto front with allocation shifts."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import experiment as ex
from repro.core.objective import size_constraint
from repro.core.pareto import pareto_sweep

try:
    art = ex.load_artifacts()
except FileNotFoundError:
    print("training reduced library first ...")
    xc = ex.ExperimentConfig(expert_steps=60, n_train_prompts=512,
                             n_val_prompts=128, n_test_per_domain=24,
                             router_epochs=3)
    ex.run_experiment(xc, verbose=True)
    art = ex.load_artifacts()

lib, pred, q_test = art["library"], art["pred"], art["q_test"]
front = pareto_sweep(pred, q_test, lib, size_constraint(lib))

sizes = lib.sizes()
print(f"{'lambda':>9} {'accuracy':>9} {'size_frac':>10}  top allocations")
for row in front["rows"]:
    alloc = np.array(row["alloc"])
    top = np.argsort(-alloc)[:3]
    tops = ", ".join(f"{lib.names[i]}:{alloc[i]:.0%}" for i in top
                     if alloc[i] > 0.01)
    print(f"{row['lam']:9.3f} {row['accuracy']:9.4f} "
          f"{row['size_frac']:10.3f}  {tops}")

base = front["rows"][0]
ok = [r for r in front["rows"] if r["accuracy"] >= base["accuracy"] - 0.05]
best = min(ok, key=lambda r: r["mean_size"])
print(f"\nheadline: {1 - best['mean_size']/base['mean_size']:.0%} compute "
      "saved within 5% accuracy of the unconstrained router "
      f"(lambda={best['lam']:.2f})")
