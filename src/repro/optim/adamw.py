"""AdamW over arbitrary pytrees (pure JAX, no optax).

Matches the paper's training recipe surface: Adam with weight decay 1e-5,
lr 5e-5, exponential decay 0.9 — all expressible as schedules here.
Moments are kept in f32 regardless of param dtype (mixed-precision safe).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params))


def adamw_update(params, grads, state: OptState, *, lr, b1=0.9, b2=0.999,
                 eps=1e-8, weight_decay=1e-5, grad_clip=1.0):
    """Returns (new_params, new_state). ``lr`` may be a float or a
    schedule fn(step)->float."""
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr

    if grad_clip and grad_clip > 0:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m2 / (1 - b1 ** step)
        vhat = v2 / (1 - b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, mu=new_m, nu=new_v)


def exp_decay_schedule(base_lr: float, decay: float, steps_per_decay: int) -> Callable:
    def fn(step):
        return base_lr * decay ** (step / steps_per_decay)
    return fn


def cosine_schedule(base_lr: float, total_steps: int, min_frac=0.1) -> Callable:
    def fn(step):
        t = jnp.clip(step / total_steps, 0.0, 1.0)
        return base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return fn


def warmup_cosine_schedule(base_lr: float, warmup: int, total_steps: int,
                           min_frac=0.0) -> Callable:
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), min_frac)
    def fn(step):
        w = jnp.clip(step / jnp.maximum(warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, base_lr * w, cos(step - warmup))
    return fn
