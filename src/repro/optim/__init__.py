from repro.optim.adamw import (adamw_init, adamw_update, OptState,
                               exp_decay_schedule, cosine_schedule,
                               warmup_cosine_schedule)

__all__ = ["adamw_init", "adamw_update", "OptState", "exp_decay_schedule",
           "cosine_schedule", "warmup_cosine_schedule"]
