"""Synthetic multi-domain corpus — the offline stand-in for the Pile.

Each domain is an order-1 Markov chain over a shared vocabulary with
 (i) a domain-private high-frequency sub-vocabulary,
 (ii) domain-specific transition sparsity (code is highly structured,
      common-crawl is diffuse),
 (iii) structural motifs (bracket pairs for code, digit runs for math).

These properties make per-domain statistics genuinely different, so expert
models trained on biased mixtures acquire differential per-prompt MLM loss
— reproducing the premise of Tryage Fig. 2 — while prompts remain
unlabeled at routing time, which is exactly the paper's learning problem.
"""

from __future__ import annotations

import dataclasses

import numpy as np

PAD, MASK, BOS = 0, 1, 2
N_SPECIAL = 4

DOMAINS = ("github", "uspto", "pubmed", "freelaw", "dm_math",
           "stackexchange", "books", "commoncrawl")

# per-domain (branching factor, private-vocab weight, motif)
_DOMAIN_PROFILE = {
    "github":        (4,  0.75, "brackets"),
    "uspto":         (8,  0.70, "legalese"),
    "pubmed":        (8,  0.70, "latinate"),
    "freelaw":       (10, 0.60, "legalese"),
    "dm_math":       (3,  0.80, "digits"),
    "stackexchange": (6,  0.55, "brackets"),
    "books":         (14, 0.45, None),
    "commoncrawl":   (20, 0.30, None),
}


@dataclasses.dataclass
class DomainCorpus:
    vocab_size: int = 512
    seed: int = 0
    shared_frac: float = 0.35   # fraction of vocab shared by all domains

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V = self.vocab_size
        usable = np.arange(N_SPECIAL, V)
        n_shared = int(len(usable) * self.shared_frac)
        self.shared_vocab = usable[:n_shared]
        rest = usable[n_shared:]
        splits = np.array_split(rest, len(DOMAINS))
        self.private_vocab = {d: s for d, s in zip(DOMAINS, splits)}

        # build per-domain transition tables: for each token, a small set of
        # plausible successors with Zipf-ish weights.
        self.tables = {}
        for d in DOMAINS:
            branch, priv_w, motif = _DOMAIN_PROFILE[d]
            drng = np.random.default_rng(
                rng.integers(0, 2**31))
            succ = np.zeros((V, branch), np.int32)
            for t in range(V):
                n_priv = max(1, int(round(branch * priv_w)))
                cand_priv = drng.choice(self.private_vocab[d], size=n_priv)
                cand_shared = drng.choice(self.shared_vocab,
                                          size=branch - n_priv)
                succ[t] = np.concatenate([cand_priv, cand_shared])
            w = 1.0 / np.arange(1, branch + 1) ** 1.2
            self.tables[d] = (succ, w / w.sum(), motif)

    # ---------------------------------------------------------------

    def sample_tokens(self, domain: str, batch: int, seq: int,
                      rng: np.random.Generator) -> np.ndarray:
        succ, w, motif = self.tables[domain]
        branch = succ.shape[1]
        out = np.empty((batch, seq), np.int32)
        cur = rng.choice(self.private_vocab[domain], size=batch)
        out[:, 0] = cur
        choices = rng.choice(branch, size=(batch, seq), p=w)
        for s in range(1, seq):
            cur = succ[cur, choices[:, s]]
            out[:, s] = cur
        if motif == "brackets":
            self._inject_brackets(out, rng)
        elif motif == "digits":
            self._inject_digit_runs(out, rng)
        return out

    def _inject_brackets(self, out, rng):
        """Paired open/close tokens at nested offsets (code-like syntax)."""
        open_t, close_t = self.shared_vocab[0], self.shared_vocab[1]
        B, S = out.shape
        for b in range(B):
            n = rng.integers(1, max(2, S // 16))
            for _ in range(n):
                i = rng.integers(0, S - 3)
                j = rng.integers(i + 2, min(S, i + 12))
                out[b, i], out[b, j] = open_t, close_t

    def _inject_digit_runs(self, out, rng):
        digits = self.shared_vocab[2:12]
        B, S = out.shape
        for b in range(B):
            i = rng.integers(0, S - 8)
            run = rng.integers(4, 8)
            out[b, i:i + run] = rng.choice(digits, size=run)

    def sample_mixture(self, weights: dict, batch: int, seq: int,
                       rng: np.random.Generator):
        """Sample a batch from a domain mixture. Returns (tokens, labels)."""
        names = list(weights)
        p = np.array([weights[n] for n in names], float)
        p /= p.sum()
        idx = rng.choice(len(names), size=batch, p=p)
        toks = np.empty((batch, seq), np.int32)
        # vectorized per-domain generation (one chain walk per domain)
        for di, name in enumerate(names):
            rows = np.where(idx == di)[0]
            if len(rows):
                toks[rows] = self.sample_tokens(name, len(rows), seq, rng)
        labels = np.array([DOMAINS.index(names[di]) for di in idx], np.int32)
        return toks, labels
