from repro.data.corpus import DOMAINS, DomainCorpus
from repro.data.batching import mlm_batch, clm_batch, BatchIterator

__all__ = ["DOMAINS", "DomainCorpus", "mlm_batch", "clm_batch", "BatchIterator"]
