"""MLM / CLM batch construction + a deterministic batch iterator."""

from __future__ import annotations

import numpy as np

from repro.data.corpus import MASK, DomainCorpus


def mlm_batch(tokens: np.ndarray, rng: np.random.Generator,
              mask_rate: float = 0.15, vocab_size: int = 512):
    """BERT-style masking: 80% [MASK], 10% random, 10% keep."""
    B, S = tokens.shape
    mask = rng.random((B, S)) < mask_rate
    # never mask position 0 so there's always context
    mask[:, 0] = False
    inputs = tokens.copy()
    r = rng.random((B, S))
    use_mask = mask & (r < 0.8)
    use_rand = mask & (r >= 0.8) & (r < 0.9)
    inputs[use_mask] = MASK
    inputs[use_rand] = rng.integers(4, vocab_size,
                                    size=int(use_rand.sum()))
    return {"tokens": inputs, "targets": tokens,
            "mask": mask.astype(np.int32)}


def clm_batch(tokens: np.ndarray):
    return {"tokens": tokens, "mask": np.ones_like(tokens, np.int32)}


class BatchIterator:
    """Deterministic stream of MLM batches from a domain mixture."""

    def __init__(self, corpus: DomainCorpus, weights: dict, batch: int,
                 seq: int, seed: int = 0, mask_rate: float = 0.15):
        self.corpus, self.weights = corpus, weights
        self.batch, self.seq, self.mask_rate = batch, seq, mask_rate
        self.rng = np.random.default_rng(seed)

    def __iter__(self):
        return self

    def __next__(self):
        toks, labels = self.corpus.sample_mixture(
            self.weights, self.batch, self.seq, self.rng)
        b = mlm_batch(toks, self.rng, self.mask_rate,
                      self.corpus.vocab_size)
        b["domain"] = labels
        return b
