from repro.checkpoint.store import (save_pytree, load_pytree,
                                    CheckpointManager)

__all__ = ["save_pytree", "load_pytree", "CheckpointManager"]
