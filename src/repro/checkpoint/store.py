"""Pytree checkpointing: npz payload + json treedef sidecar.

``CheckpointManager`` implements the paper's recipe of keeping the best
validation checkpoint (plus rolling last-k), which the router trainer uses
for early stopping.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

_SEP = "/"


def _flatten_with_paths(tree):
    flat = {}

    def rec(node, prefix):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(node[k], prefix + [str(k)])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(v, prefix + [str(i)])
        else:
            flat[_SEP.join(prefix)] = np.asarray(node)

    rec(tree, [])
    return flat


def _tree_structure(tree):
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _tree_structure(v) for k, v in tree.items()}}
    if isinstance(tree, tuple):
        return {"__kind__": "tuple",
                "items": [_tree_structure(v) for v in tree]}
    if isinstance(tree, list):
        return {"__kind__": "list",
                "items": [_tree_structure(v) for v in tree]}
    return {"__kind__": "leaf", "dtype": str(np.asarray(tree).dtype)}


def _rebuild(struct, flat, prefix):
    kind = struct["__kind__"]
    if kind == "dict":
        return {k: _rebuild(v, flat, prefix + [k])
                for k, v in struct["items"].items()}
    if kind in ("tuple", "list"):
        seq = [_rebuild(v, flat, prefix + [str(i)])
               for i, v in enumerate(struct["items"])]
        return tuple(seq) if kind == "tuple" else seq
    arr = flat[_SEP.join(prefix)]
    if struct.get("dtype") == "bfloat16":
        import ml_dtypes
        arr = arr.view(ml_dtypes.bfloat16)
    return arr


def save_pytree(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tree = jax.tree.map(np.asarray, tree)
    flat = _flatten_with_paths(tree)
    # npz has no bf16 support: store as uint16 bits, restore from the
    # dtype recorded in the json structure sidecar.
    flat = {k: (v.view(np.uint16) if v.dtype.name == "bfloat16" else v)
            for k, v in flat.items()}
    np.savez(path + ".npz", **flat)
    with open(path + ".json", "w") as f:
        json.dump(_tree_structure(tree), f)


def load_pytree(path: str):
    with open(path + ".json") as f:
        struct = json.load(f)
    with np.load(path + ".npz") as z:
        flat = {k: z[k] for k in z.files}
    return _rebuild(struct, flat, [])


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 2):
        self.dir = directory
        self.keep_last = keep_last
        self.best_metric = float("inf")
        os.makedirs(directory, exist_ok=True)
        self._steps: list[int] = []

    def save(self, step: int, tree, metric: float | None = None) -> None:
        path = os.path.join(self.dir, f"step_{step:08d}")
        save_pytree(path, tree)
        self._steps.append(step)
        if metric is not None and metric < self.best_metric:
            self.best_metric = metric
            for ext in (".npz", ".json"):
                shutil.copyfile(path + ext,
                                os.path.join(self.dir, "best" + ext))
        while len(self._steps) > self.keep_last:
            old = self._steps.pop(0)
            for ext in (".npz", ".json"):
                p = os.path.join(self.dir, f"step_{old:08d}" + ext)
                if os.path.exists(p):
                    os.remove(p)

    def load_best(self):
        return load_pytree(os.path.join(self.dir, "best"))

    def load_step(self, step: int):
        return load_pytree(os.path.join(self.dir, f"step_{step:08d}"))
