"""Activation-sharding constraints via an ambient context.

XLA's sharding propagation can silently drop the batch sharding deep in a
network (observed: attention scores materializing the full global batch per
device).  Production frameworks pin activation shardings explicitly; we do
the same with ``shard_act(x, logical_axes)``, which no-ops outside an
``activation_sharding(mesh, rules)`` context so model code stays runnable
on a single device.
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding

from repro.sharding.rules import DEFAULT_RULES, LogicalRules, logical_to_spec

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "activation_sharding", default=None)


def batch_sharding(mesh, ndim: int, dim_sizes=None,
                   rules: LogicalRules = DEFAULT_RULES) -> NamedSharding:
    """``NamedSharding`` that shards the leading (batch) dim over the
    mesh's ``data`` axis and replicates the rest — the placement the
    serving engine's data-parallel routing stage puts on admission
    batches (tokens and per-request lambda rows).  Divisibility-aware
    via ``logical_to_spec``: pass ``dim_sizes`` to fall back to
    replication when the batch does not divide the data axis."""
    spec = logical_to_spec(mesh, ("batch",) + (None,) * (ndim - 1),
                           dim_sizes, rules)
    return NamedSharding(mesh, spec)


def replicated_sharding(mesh) -> NamedSharding:
    """Fully-replicated ``NamedSharding`` (router params on the serving
    mesh: every data shard scores with the same snapshot)."""
    return NamedSharding(mesh, logical_to_spec(mesh, (), None,
                                               DEFAULT_RULES))


@contextlib.contextmanager
def activation_sharding(mesh, rules: LogicalRules):
    tok = _CTX.set((mesh, rules))
    try:
        yield
    finally:
        _CTX.reset(tok)


def shard_act(x, logical_axes: tuple):
    """Constrain activation ``x`` to the ambient mesh/rules (no-op if none)."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(logical_axes) != x.ndim:
        return x
    spec = logical_to_spec(mesh, logical_axes, x.shape, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
