"""Activation-sharding constraints via an ambient context.

XLA's sharding propagation can silently drop the batch sharding deep in a
network (observed: attention scores materializing the full global batch per
device).  Production frameworks pin activation shardings explicitly; we do
the same with ``shard_act(x, logical_axes)``, which no-ops outside an
``activation_sharding(mesh, rules)`` context so model code stays runnable
on a single device.
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding

from repro.sharding.rules import LogicalRules, logical_to_spec

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "activation_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(mesh, rules: LogicalRules):
    tok = _CTX.set((mesh, rules))
    try:
        yield
    finally:
        _CTX.reset(tok)


def shard_act(x, logical_axes: tuple):
    """Constrain activation ``x`` to the ambient mesh/rules (no-op if none)."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(logical_axes) != x.ndim:
        return x
    spec = logical_to_spec(mesh, logical_axes, x.shape, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
