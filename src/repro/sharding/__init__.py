from repro.sharding.context import activation_sharding, shard_act
from repro.sharding.rules import (
    LogicalRules,
    DEFAULT_RULES,
    MULTIPOD_RULES,
    logical_to_spec,
    tree_logical_to_sharding,
    tree_logical_to_spec,
)

__all__ = [
    "activation_sharding",
    "shard_act",
    "LogicalRules",
    "DEFAULT_RULES",
    "MULTIPOD_RULES",
    "logical_to_spec",
    "tree_logical_to_sharding",
    "tree_logical_to_spec",
]
