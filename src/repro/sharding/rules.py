"""Logical-axis -> mesh-axis sharding rules.

Every parameter/activation in the framework is annotated with a tuple of
*logical* axis names (e.g. ``("embed", "mlp")``).  A ``LogicalRules`` maps
logical names to mesh axis names (or tuples of mesh axes).  The mapping is
divisibility-aware: a rule only applies when the concrete dimension size is
divisible by the mesh-axis product, otherwise the dim is replicated.  This
is what lets one rule-set serve architectures with 4..64 heads, vocab 504
.. 262144, expert counts 8/16/60 on a fixed 16x16 (x2 pods) mesh.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class LogicalRules:
    """Mapping from logical axis name -> mesh axis (str | tuple | None)."""

    rules: Mapping[str, object]

    def mesh_axes_for(self, logical: str):
        return self.rules.get(logical, None)


# Logical vocabulary used across the framework:
#   batch    - global batch dim                  -> data (+ pod)
#   seq      - sequence dim of activations       -> unsharded (default)
#   cache    - KV-cache sequence dim             -> sharded at decode
#   embed    - d_model rows of weight matrices   -> fsdp axis ("data")
#   mlp      - d_ff / hidden of MLPs             -> model
#   heads    - query heads                       -> model
#   kv_heads - kv heads (GQA, often small)       -> model (if divisible)
#   head_dim - per-head dim                      -> unsharded
#   vocab    - vocabulary                        -> model
#   expert   - MoE expert dim                    -> model (fallback data)
#   state    - SSM/recurrent state dim           -> model
#   conv     - conv kernel taps                  -> unsharded
#   norm     - norm scales                       -> unsharded

DEFAULT_RULES = LogicalRules(
    rules={
        "batch": "data",
        "seq": None,
        "cache": "model",
        "embed": "data",  # FSDP: shard d_model rows of weights over data
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "vocab": "model",
        "expert": "model",
        "capacity": "data",  # MoE dispatch-buffer capacity dim
        "state": None,
        "inner": "model",  # SSM expanded inner dim
        "conv": None,
        "norm": None,
        "act_embed": None,  # activations keep d_model replicated
    }
)

MULTIPOD_RULES = LogicalRules(
    rules={
        **DEFAULT_RULES.rules,
        "batch": ("pod", "data"),
        "embed": ("pod", "data"),
    }
)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return math.prod(mesh.shape[a] for a in axes)


def logical_to_spec(
    mesh: Mesh,
    logical_axes: Sequence[str | None],
    dim_sizes: Sequence[int] | None,
    rules: LogicalRules,
) -> P:
    """Build a PartitionSpec for one array.

    A mesh axis is assigned to a dim only if the dim size divides evenly;
    each mesh axis may be used at most once per array (SPMD requirement).
    """
    used: set[str] = set()
    out = []
    for i, name in enumerate(logical_axes):
        axes = rules.mesh_axes_for(name) if name is not None else None
        if axes is None:
            out.append(None)
            continue
        axes_tuple = (axes,) if isinstance(axes, str) else tuple(axes)
        # drop axes already claimed by an earlier dim of this array and
        # keep the usable remainder (e.g. ("model","data") with "model"
        # taken by the expert dim still shards over "data")
        axes_tuple = tuple(a for a in axes_tuple if a not in used)
        if not axes_tuple:
            out.append(None)
            continue
        size = _axis_size(mesh, axes_tuple)
        if dim_sizes is not None and dim_sizes[i] % size != 0:
            # Try progressively shorter prefixes of the axis tuple.
            placed = False
            for k in range(len(axes_tuple) - 1, 0, -1):
                sub = axes_tuple[:k]
                ssize = _axis_size(mesh, sub)
                if dim_sizes[i] % ssize == 0:
                    out.append(sub if len(sub) > 1 else sub[0])
                    used.update(sub)
                    placed = True
                    break
            if not placed:
                out.append(None)
            continue
        used.update(axes_tuple)
        out.append(axes_tuple[0] if len(axes_tuple) == 1 else axes_tuple)
    return P(*out)


def tree_logical_to_spec(mesh: Mesh, logical_tree, shape_tree, rules: LogicalRules):
    """Map a pytree of logical-axes tuples (+ matching shapes) to PartitionSpecs."""

    def one(logical, shaped):
        shape = shaped.shape if hasattr(shaped, "shape") else tuple(shaped)
        assert len(logical) == len(shape), (logical, shape)
        return logical_to_spec(mesh, logical, shape, rules)

    return jax.tree.map(
        one, logical_tree, shape_tree, is_leaf=lambda x: isinstance(x, tuple)
    )


def tree_logical_to_sharding(mesh: Mesh, logical_tree, shape_tree, rules: LogicalRules):
    specs = tree_logical_to_spec(mesh, logical_tree, shape_tree, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
