"""Full model assembly: embed -> scan(units) [+ remainder layers] -> norm ->
logits, with train / prefill / decode entry points and loss functions.

Sharding contract: ``init_model`` returns ``(params, logical)``; stacked
unit params carry a leading ``layers`` axis (replicated).  The scan over
units means XLA traces each hetero-unit exactly once regardless of depth —
an 80-layer 72B model lowers as fast as a 2-layer one.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.common import ModelConfig
from repro.models.layers import (apply_embedding, apply_norm, apply_unembed,
                                 init_embedding, init_norm)
from repro.sharding.context import shard_act


def _num_full_units(cfg: ModelConfig):
    unit = len(cfg.layer_pattern)
    return cfg.num_layers // unit, cfg.num_layers % unit


def init_model(key, cfg: ModelConfig):
    dtype = cfg.jnp_dtype
    U, rem = _num_full_units(cfg)
    k_embed, k_units, k_rem, k_head = jax.random.split(key, 4)

    params, logical = {}, {}
    params["embed"], logical["embed"] = init_embedding(
        k_embed, cfg.vocab_size, cfg.d_model, dtype)

    unit_keys = jax.random.split(k_units, U)
    params["units"] = jax.vmap(lambda k: blocks.init_unit(k, cfg, dtype)[0])(unit_keys)
    _box = {}

    def _unit_params_only(k):
        p, l = blocks.init_unit(k, cfg, dtype)
        _box["logical"] = l
        return p

    from repro.core.rngs import seeded_key  # local: core imports models

    jax.eval_shape(_unit_params_only, seeded_key(0))
    unit_logical = _box["logical"]
    logical["units"] = jax.tree.map(
        lambda ax: ("layers",) + ax, unit_logical,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))

    if rem:
        ks = jax.random.split(k_rem, rem)
        params["rem"], logical["rem"] = {}, {}
        for j in range(rem):
            kind = cfg.layer_pattern[j]
            params["rem"][f"l{j}"], logical["rem"][f"l{j}"] = blocks.init_block(
                ks[j], cfg, kind, cfg.moe_pattern[j], dtype)

    params["final_norm"], logical["final_norm"] = init_norm(
        cfg.d_model, dtype, cfg.norm_kind)
    if not cfg.tie_embeddings:
        from repro.models.layers import init_dense
        params["head"], logical["head"] = init_dense(
            k_head, cfg.d_model, cfg.vocab_size, dtype, axes=("embed", "vocab"))
    return params, logical


def init_model_logical(cfg: ModelConfig):
    """(abstract params, logical axes) without allocating anything."""
    box = {}

    def f(k):
        p, l = init_model(k, cfg)
        box["l"] = l
        return p

    from repro.core.rngs import seeded_key  # local: core imports models

    abs_params = jax.eval_shape(f, seeded_key(0))
    return abs_params, box["l"]


def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int):
    """Stacked per-unit state + remainder-layer state."""
    dtype = cfg.jnp_dtype
    U, rem = _num_full_units(cfg)
    one = blocks.init_unit_state(cfg, batch, cache_len, dtype)
    stacked = jax.tree.map(lambda a: jnp.broadcast_to(a, (U,) + a.shape), one)
    state = {"units": stacked}
    if rem:
        unit = len(cfg.layer_pattern)
        state["rem"] = {
            f"l{j}": blocks.init_block_state(
                cfg, cfg.layer_pattern[j], batch, cache_len, dtype,
                layer_idx=U * unit + j)
            for j in range(rem)}
    return state


def decode_state_logical(cfg: ModelConfig):
    U, rem = _num_full_units(cfg)
    one = blocks.unit_state_logical(cfg)
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    state = {"units": jax.tree.map(lambda ax: ("layers",) + ax, one, is_leaf=is_ax)}
    if rem:
        state["rem"] = {f"l{j}": blocks.block_state_logical(cfg.layer_pattern[j])
                        for j in range(rem)}
    return state


def _embed_in(params, cfg, batch):
    """batch: {"tokens": ids} or {"embeds": float (B,S,d)}."""
    if "embeds" in batch and batch["embeds"] is not None:
        x = batch["embeds"].astype(cfg.jnp_dtype)
    else:
        x = apply_embedding(params["embed"], batch["tokens"])
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _positions_for(cfg: ModelConfig, B, S, offset=0):
    pos = jnp.arange(S, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.attn.use_mrope:
        return jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def forward(params, cfg: ModelConfig, batch, *, mode: str, state=None,
            index=None, remat=True, attn_impl="xla", positions=None,
            unit_group: int = 1, cache_capacity=None):
    """Shared forward. Returns (logits, new_state, aux)."""
    x = _embed_in(params, cfg, batch)
    x = shard_act(x, ("batch", "seq", "act_embed"))
    B, S = x.shape[0], x.shape[1]
    if positions is None:
        offset = index if mode == "decode" else 0
        positions = _positions_for(cfg, B, S, offset)
    U, rem = _num_full_units(cfg)

    def unit_body(carry, xs):
        h = carry
        unit_params, unit_state = xs
        h, new_state, aux = blocks.apply_unit(
            unit_params, h, cfg, unit_base_layer=0, mode=mode,
            positions=positions, state=unit_state, index=index,
            attn_impl=attn_impl, cache_capacity=cache_capacity)
        return h, (new_state, aux)

    body = jax.checkpoint(unit_body) if (remat and mode in ("train", "encode")) else unit_body
    states_in = state["units"] if state is not None else None
    if states_in is None:
        # dummy per-unit state for scan xs when not decoding/prefilling
        if mode == "prefill":
            states_in = init_decode_state(cfg, B, S)["units"]
        else:
            states_in = jnp.zeros((U,), jnp.float32)  # placeholder

    if mode in ("train", "encode"):
        # sqrt-depth remat: scan over groups of ``unit_group`` units, so
        # only U/unit_group residual-stream boundaries are stored for the
        # backward pass (each group is recomputed inside its VJP).
        g = unit_group if (unit_group > 1 and U % unit_group == 0) else 1

        def group_body(carry, group_params):
            h = carry
            aux_g = jnp.zeros((), jnp.float32)
            for i in range(g):
                up = jax.tree.map(lambda a: a[i], group_params)
                h, _, aux = blocks.apply_unit(
                    up, h, cfg, unit_base_layer=0, mode=mode,
                    positions=positions, state=None, index=index,
                    attn_impl=attn_impl)
                aux_g = aux_g + aux
            return h, aux_g

        gbody = jax.checkpoint(group_body) if remat else group_body
        units_g = jax.tree.map(
            lambda a: a.reshape((U // g, g) + a.shape[1:]), params["units"])
        x, auxs = jax.lax.scan(gbody, x, units_g)
        new_states = None
    else:
        x, (new_unit_states, auxs) = jax.lax.scan(
            body, x, (params["units"], states_in))
        new_states = {"units": new_unit_states}

    aux = jnp.sum(auxs)

    if rem:
        if new_states is not None:
            new_states["rem"] = {}
        for j in range(rem):
            st = state["rem"][f"l{j}"] if (state is not None and "rem" in state) else None
            if st is None and mode == "prefill":
                st = blocks.init_block_state(
                    cfg, cfg.layer_pattern[j], B, S, cfg.jnp_dtype,
                    layer_idx=U * len(cfg.layer_pattern) + j)
            x, st2, aux_j = blocks.apply_block(
                params["rem"][f"l{j}"], x, cfg, cfg.layer_pattern[j],
                cfg.moe_pattern[j], mode=mode, layer_idx=U * len(cfg.layer_pattern) + j,
                positions=positions, state=st, index=index, attn_impl=attn_impl,
                cache_capacity=cache_capacity)
            aux = aux + aux_j
            if new_states is not None:
                new_states["rem"][f"l{j}"] = st2

    x = apply_norm(params["final_norm"], x, cfg.norm_eps, cfg.norm_kind)
    if mode == "encode":
        return x, new_states, aux
    if cfg.tie_embeddings:
        logits = apply_unembed(params["embed"], x)
    else:
        from repro.models.layers import apply_dense
        logits = apply_dense(params["head"], x)
    logits = shard_act(logits, ("batch", "seq", "vocab"))
    return logits, new_states, aux


def encode(params, cfg: ModelConfig, batch, remat=False, attn_impl="xla"):
    """Final-norm hidden states (B, S, d) — used by the Tryage router."""
    hidden, _, _ = forward(params, cfg, batch, mode="encode", remat=remat,
                           attn_impl=attn_impl)
    return hidden


# ------------------------------------------------------------- losses

def cross_entropy(logits, targets, mask):
    """Masked mean CE in f32. logits (B,S,V); targets (B,S); mask (B,S).

    The gold logit is picked with a one-hot contraction rather than a
    gather: a gather over the vocab axis forces XLA to all-gather
    model-sharded logits, while the contraction partitions cleanly (the
    one-hot is fused into the reduction and never materializes).
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
    gold = jnp.einsum("...v,...v->...", logits, onehot)
    nll = logz - gold
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def lm_loss(params, cfg: ModelConfig, batch, remat=True, attn_impl="xla",
            unit_group: int = 1):
    """Causal-LM (decoder) or MLM (encoder) loss. Returns (loss, metrics)."""
    logits, _, aux = forward(params, cfg, batch, mode="train", remat=remat,
                             attn_impl=attn_impl, unit_group=unit_group)
    if cfg.is_encoder:
        targets, mask = batch["targets"], batch["mask"]
        ce = cross_entropy(logits, targets, mask)
    else:
        tokens = batch.get("targets")
        if tokens is None:
            tokens = batch["tokens"]
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones_like(tokens)
        ce = cross_entropy(logits[:, :-1], tokens[:, 1:], mask[:, 1:])
    moe_w = cfg.moe.router_aux_weight if cfg.moe else 0.0
    loss = ce + moe_w * aux
    return loss, {"ce": ce, "aux": aux}


def prefill(params, cfg: ModelConfig, batch, attn_impl="xla",
            cache_capacity=None):
    logits, state, _ = forward(params, cfg, batch, mode="prefill",
                               attn_impl=attn_impl,
                               cache_capacity=cache_capacity)
    return logits, state


def decode_step(params, cfg: ModelConfig, token_batch, state, index,
                attn_impl="xla"):
    """token_batch: {"tokens": (B,1)} (or embeds). index: scalar position."""
    logits, state, _ = forward(params, cfg, token_batch, mode="decode",
                               state=state, index=index, attn_impl=attn_impl)
    return logits[:, -1], state


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
