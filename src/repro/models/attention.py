"""Grouped-query attention: full-sequence (train/prefill) and cached decode.

Memory discipline: scores are never materialized at (S, T) — the query axis
is processed in chunks via lax.scan, so the transient is (B, H, chunk, T).
GQA is implemented by locally repeating K/V to the full head count *after*
projection; the head axis stays sharded over the ``model`` mesh axis and the
repeat lowers to a local slice per shard (no resharding).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import _init, apply_rope, apply_mrope
from repro.sharding.context import shard_act

NEG_INF = -2.3819763e38  # close to f32 min, safe in exp


def init_attention(key, cfg: ModelConfig, dtype):
    d, H, KV = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": _init(ks[0], (d, H, hd), s, dtype),
        "wk": _init(ks[1], (d, KV, hd), s, dtype),
        "wv": _init(ks[2], (d, KV, hd), s, dtype),
        "wo": _init(ks[3], (H, hd, d), 1.0 / math.sqrt(H * hd), dtype),
    }
    l = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.attn.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype=dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype=dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype=dtype)
        l["bq"] = ("heads", "head_dim")
        l["bk"] = ("kv_heads", "head_dim")
        l["bv"] = ("kv_heads", "head_dim")
    return p, l


def _project_qkv(p, x, cfg: ModelConfig, positions):
    q = jnp.einsum("...d,dhk->...hk", x, p["wq"])
    k = jnp.einsum("...d,dhk->...hk", x, p["wk"])
    v = jnp.einsum("...d,dhk->...hk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    a = cfg.attn
    if a.use_mrope:
        q = apply_mrope(q, positions, a.mrope_sections, a.rope_theta)
        k = apply_mrope(k, positions, a.mrope_sections, a.rope_theta)
    elif a.rope_theta > 0:
        q = apply_rope(q, positions, a.rope_theta)
        k = apply_rope(k, positions, a.rope_theta)
    q = shard_act(q, ("batch", "seq", "heads", "head_dim"))
    k = shard_act(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = shard_act(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def _repeat_kv(k, v, H):
    KV = k.shape[2]
    if KV == H:
        return k, v
    G = H // KV
    rep = lambda a: jnp.repeat(a, G, axis=2)
    return rep(k), rep(v)


def _pick_q_chunk(S, target=1024):
    c = min(S, target)
    while S % c:
        c -= 1
    return c


def _sdpa_chunked(q, k, v, bias_fn, softcap=0.0, q_chunk=1024):
    """q: (B,S,H,hd); k/v: (B,T,H,hd) (already head-repeated).

    ``bias_fn(q_offset, q_len)`` -> (q_len, T) additive f32 bias, computed
    per chunk so the (S, T) mask never materializes.
    """
    B, S, H, hd = q.shape
    scale = 1.0 / math.sqrt(hd)

    def block(qb, offset):
        logits = jnp.einsum("bshd,bthd->bhst", qb, k).astype(jnp.float32)
        logits = shard_act(logits, ("batch", "heads", "seq", "seq"))
        logits = logits * scale
        if softcap > 0:
            logits = softcap * jnp.tanh(logits / softcap)
        logits = logits + bias_fn(offset, qb.shape[1])
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhst,bthd->bshd", w.astype(v.dtype), v)
        return shard_act(out, ("batch", "seq", "heads", "head_dim"))

    ck = _pick_q_chunk(S, q_chunk)
    if ck == S:
        return block(q, 0)
    n = S // ck
    qs = jnp.moveaxis(q.reshape(B, n, ck, H, hd), 1, 0)

    def body(_, xs):
        i, qb = xs
        return None, block(qb, i * ck)

    _, outs = jax.lax.scan(body, None, (jnp.arange(n), qs))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)


def attend_full(p, x, cfg: ModelConfig, positions, window=0, impl="xla"):
    """Full-sequence attention for train/prefill. Returns (out, (k, v))."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    a = cfg.attn
    S = x.shape[-2]
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(
            q, k, v, causal=a.causal, window=window, softcap=a.softcap)
    else:
        kr, vr = _repeat_kv(k, v, cfg.num_heads)

        def bias_fn(offset, q_len):
            qi = jnp.arange(q_len)[:, None] + offset
            kj = jnp.arange(S)[None, :]
            ok = jnp.ones((q_len, S), bool)
            if a.causal:
                ok &= kj <= qi
            if window > 0:
                ok &= kj > qi - window
            return jnp.where(ok, 0.0, NEG_INF)

        out = _sdpa_chunked(q, kr, vr, bias_fn, a.softcap)
    y = jnp.einsum("...hk,hkd->...d", out, p["wo"])
    return y, (k, v)


def init_kv_cache(batch, max_len, cfg: ModelConfig, dtype):
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (batch, max_len, KV, hd)
    return {"k": jnp.zeros(shape, dtype=dtype), "v": jnp.zeros(shape, dtype=dtype)}


KV_CACHE_LOGICAL = {"k": ("batch", "cache", "kv_heads", "head_dim"),
                    "v": ("batch", "cache", "kv_heads", "head_dim")}


def prefill_cache_from_kv(k, v, window, dtype, capacity=None):
    """Convert prefill-computed (B,S,KV,hd) k/v into the decode ring cache.

    ``capacity`` (default S) is the allocated cache length for non-window
    layers; pass S + max_new_tokens when decoding will continue.  For
    window layers the cache is the ring of ``window`` slots with the
    invariant slot == abs_pos % window.
    """
    S = k.shape[1]
    if window <= 0:
        cap = capacity or S
        if cap > S:
            pad = [(0, 0), (0, cap - S), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return {"k": k.astype(dtype), "v": v.astype(dtype)}
    if S <= window:
        if S < window:
            pad = [(0, 0), (0, window - S), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return {"k": k.astype(dtype), "v": v.astype(dtype)}
    k, v = k[:, -window:], v[:, -window:]
    shift = S % window
    return {"k": jnp.roll(k, shift, axis=1).astype(dtype),
            "v": jnp.roll(v, shift, axis=1).astype(dtype)}


def attend_decode(p, x, cache, index, cfg: ModelConfig, positions, window=0):
    """Single-token decode against a (ring-buffer) KV cache.

    x: (B, 1, d); cache k/v: (B, T, KV, hd); ``index`` is the absolute
    position of the new token.  Sliding-window layers allocate T == window
    and wrap; RoPE is applied at write time so ring scrambling is harmless
    (softmax is order-invariant, validity is masked from absolute indices).
    """
    q, k1, v1 = _project_qkv(p, x, cfg, positions)
    T = cache["k"].shape[1]
    write = jnp.mod(index, T)
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k1.astype(cache["k"].dtype), write, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v1.astype(cache["v"].dtype), write, axis=1)
    new_cache = {"k": k, "v": v}
    kr, vr = _repeat_kv(k, v, cfg.num_heads)

    def bias_fn(offset, q_len):
        kj = jnp.arange(T)[None, :]
        ok = (kj <= index) | (index >= T)
        if 0 < window < T:
            ok &= kj > index - window
        return jnp.where(ok, 0.0, NEG_INF)

    out = _sdpa_chunked(q, kr, vr, bias_fn, cfg.attn.softcap)
    y = jnp.einsum("...hk,hkd->...d", out, p["wo"])
    return y, new_cache


def layer_window(cfg: ModelConfig, layer_idx: int) -> int:
    """Resolve sliding-window size for a given layer under the config pattern."""
    a = cfg.attn
    if a.sliding_window <= 0:
        return 0
    if a.window_pattern == "all_local":
        return a.sliding_window
    if a.window_pattern == "gemma":
        return 0 if (layer_idx % a.global_every == a.global_every - 1) else a.sliding_window
    if a.window_pattern == "starcoder_swa":
        return a.sliding_window
    return 0
