"""Functional NN primitives.

Every ``init_*`` returns ``(params, logical)`` where ``logical`` mirrors
``params`` leaf-for-leaf with tuples of logical axis names consumed by
``repro.sharding``.  Apply functions are pure.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _init(key, shape, scale, dtype):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------- norms

def init_norm(d, dtype, kind="rmsnorm"):
    p = {"scale": jnp.ones((d,), dtype=jnp.float32)}
    l = {"scale": ("norm",)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype=jnp.float32)
        l["bias"] = ("norm",)
    return p, l


def apply_norm(p, x, eps=1e-6, kind="rmsnorm"):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------- dense

def init_dense(key, d_in, d_out, dtype, axes=("embed", "mlp"), bias=False):
    scale = 1.0 / math.sqrt(d_in)
    p = {"w": _init(key, (d_in, d_out), scale, dtype)}
    l = {"w": axes}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
        l["b"] = (axes[-1],)
    return p, l


def apply_dense(p, x):
    y = jnp.einsum("...i,io->...o", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


# ------------------------------------------------------------ embedding

def init_embedding(key, vocab, d, dtype):
    # 1/sqrt(d) keeps tied-unembed logits O(1) at init; embed_scale configs
    # multiply activations back up by sqrt(d).
    p = {"table": _init(key, (vocab, d), 1.0 / math.sqrt(d), dtype)}
    l = {"table": ("vocab", "embed")}
    return p, l


def apply_embedding(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def apply_unembed(p, x):
    return jnp.einsum("...d,vd->...v", x, p["table"])


# ----------------------------------------------------------------- rope

def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta=10000.0):
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    ang = ang[..., None, :]  # broadcast over heads: (..., S, 1, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta=10000.0):
    """Qwen2-VL multimodal RoPE.

    positions3: (3, ..., S) — temporal / height / width position ids.  The
    head_dim/2 frequency slots are partitioned into ``sections`` (t, h, w);
    each section takes its angle from the corresponding position stream.
    For pure text all three streams are equal and this reduces to RoPE.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    secs = jnp.cumsum(jnp.asarray((0,) + tuple(sections)))
    slot = jnp.arange(d // 2)
    which = jnp.clip(jnp.searchsorted(secs, slot, side="right") - 1, 0, 2)  # (d/2,)
    # gather per-slot positions: (..., S, d/2)
    pos = jnp.stack([positions3[i] for i in range(3)], axis=-1)  # (..., S, 3)
    pos_slot = jnp.take_along_axis(
        pos.astype(jnp.float32),
        jnp.broadcast_to(which, pos.shape[:-1] + (d // 2,)),
        axis=-1,
    )
    ang = (pos_slot * freqs)[..., None, :]  # (..., S, 1, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ mlp

def init_mlp(key, d_model, d_ff, dtype, act="silu"):
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "silu":  # swiglu
        p, l = {}, {}
        p["wi"], l["wi"] = _init(k1, (d_model, d_ff), 1 / math.sqrt(d_model), dtype), ("embed", "mlp")
        p["wg"], l["wg"] = _init(k2, (d_model, d_ff), 1 / math.sqrt(d_model), dtype), ("embed", "mlp")
        p["wo"], l["wo"] = _init(k3, (d_ff, d_model), 1 / math.sqrt(d_ff), dtype), ("mlp", "embed")
        return p, l
    p, l = {}, {}
    p["wi"], l["wi"] = _init(k1, (d_model, d_ff), 1 / math.sqrt(d_model), dtype), ("embed", "mlp")
    p["wo"], l["wo"] = _init(k3, (d_ff, d_model), 1 / math.sqrt(d_ff), dtype), ("mlp", "embed")
    return p, l


def apply_mlp(p, x, act="silu"):
    if "wg" in p:
        h = jax.nn.silu(jnp.einsum("...d,df->...f", x, p["wi"])) * jnp.einsum(
            "...d,df->...f", x, p["wg"])
    else:
        h = jnp.einsum("...d,df->...f", x, p["wi"])
        h = jax.nn.gelu(h) if act == "gelu" else jax.nn.silu(h)
    return jnp.einsum("...f,fd->...d", h, p["wo"])
