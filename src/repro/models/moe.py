"""Mixture-of-Experts MLP with top-k routing and capacity-based dispatch.

Dispatch is sort-based (argsort over flattened (token, choice) pairs) and
scatter/gather-shaped so the expert dimension can shard over the ``model``
mesh axis — the TPU-idiomatic analogue of the all-to-all dispatch in
GShard/Switch.  Shared experts (Qwen2-MoE) run densely on every token.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import _init, init_mlp, apply_mlp
from repro.sharding.context import shard_act


def init_moe(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    dff = m.d_ff_expert or cfg.d_ff
    k_router, k_exp, k_shared = jax.random.split(key, 3)
    E = m.num_experts
    s_in, s_out = 1 / math.sqrt(d), 1 / math.sqrt(dff)
    ks = jax.random.split(k_exp, 3)
    p = {
        "router": _init(k_router, (d, E), s_in, jnp.float32),
        "wi": _init(ks[0], (E, d, dff), s_in, dtype),
        "wg": _init(ks[1], (E, d, dff), s_in, dtype),
        "wo": _init(ks[2], (E, dff, d), s_out, dtype),
    }
    l = {
        "router": ("embed", "expert"),
        "wi": ("expert", "embed", "mlp"),
        "wg": ("expert", "embed", "mlp"),
        "wo": ("expert", "mlp", "embed"),
    }
    if m.num_shared_experts:
        p["shared"], l["shared"] = init_mlp(
            k_shared, d, dff * m.num_shared_experts, dtype, act=cfg.act)
    return p, l


def apply_moe(p, x, cfg: ModelConfig, capacity_factor=None):
    """x: (..., d). Returns (y, aux_loss)."""
    m = cfg.moe
    E, K = m.num_experts, m.top_k
    lead = x.shape[:-1]
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    T = xt.shape[0]
    cf = capacity_factor if capacity_factor is not None else m.capacity_factor
    C = max(K, int(math.ceil(T / E * cf * K)))

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, K)               # (T, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch-style).
    me = probs.mean(axis=0)                                   # (E,)
    ce = jnp.zeros((E,)).at[gate_idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    # --- sort-based dispatch ------------------------------------------
    N = T * K
    flat_e = gate_idx.reshape(N)
    flat_t = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")    # (E,)
    pos_sorted = jnp.arange(N) - first[sorted_e]
    pos = jnp.zeros((N,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < C

    safe_pos = jnp.where(keep, pos, C - 1)
    buf = jnp.zeros((E, C, d), x.dtype)
    contrib = jnp.where(keep[:, None], xt[flat_t], 0).astype(x.dtype)
    buf = buf.at[flat_e, safe_pos].add(contrib, mode="drop")
    buf = shard_act(buf, ("expert", "capacity", "act_embed"))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wi"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["wg"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"])          # (E, C, d)
    out_buf = shard_act(out_buf, ("expert", "capacity", "act_embed"))

    gathered = out_buf[flat_e, safe_pos]                      # (N, d)
    w = jnp.where(keep, gate_w.reshape(N), 0.0).astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[flat_t].add(gathered * w[:, None])

    if "shared" in p:
        y = y + apply_mlp(p["shared"], xt, act=cfg.act)

    return y.reshape(*lead, d), aux
