"""Per-layer blocks (attn / mamba / mlstm / slstm, dense-MLP or MoE) and the
repeating-unit machinery that lets heterogeneous interleaves (Jamba, xLSTM,
Gemma-3 local:global) compile as a single lax.scan over units."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import ssm as ssm_lib
from repro.models.common import ModelConfig
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm
from repro.models.moe import apply_moe, init_moe
from repro.sharding.context import shard_act


def _has_mlp(cfg: ModelConfig) -> bool:
    return cfg.d_ff > 0


def init_block(key, cfg: ModelConfig, kind: str, use_moe: bool, dtype):
    ks = jax.random.split(key, 4)
    p, l = {}, {}
    p["norm1"], l["norm1"] = init_norm(cfg.d_model, dtype, cfg.norm_kind)
    if kind == "attn":
        p["mix"], l["mix"] = attn_lib.init_attention(ks[0], cfg, dtype)
    elif kind == "mamba":
        p["mix"], l["mix"] = ssm_lib.init_mamba(ks[0], cfg, dtype)
    elif kind == "mlstm":
        p["mix"], l["mix"] = ssm_lib.init_mlstm(ks[0], cfg, dtype)
    elif kind == "slstm":
        p["mix"], l["mix"] = ssm_lib.init_slstm(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if _has_mlp(cfg):
        p["norm2"], l["norm2"] = init_norm(cfg.d_model, dtype, cfg.norm_kind)
        if use_moe:
            p["mlp"], l["mlp"] = init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"], l["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype, cfg.act)
    return p, l


def init_block_state(cfg: ModelConfig, kind: str, batch: int, cache_len: int,
                     dtype, layer_idx: int = 0):
    """Decode-time recurrent state / KV cache for one layer.

    Sliding-window layers get a ring buffer of ``min(window, cache_len)``
    slots — this is what makes 500k-context decode of local-attention
    architectures memory-feasible.
    """
    if kind == "attn":
        window = attn_lib.layer_window(cfg, layer_idx)
        if window > 0:
            cache_len = min(cache_len, window)
        return attn_lib.init_kv_cache(batch, cache_len, cfg, dtype)
    if kind == "mamba":
        return ssm_lib.init_mamba_state(batch, cfg, dtype)
    if kind == "mlstm":
        return ssm_lib.init_mlstm_state(batch, cfg)
    if kind == "slstm":
        return ssm_lib.init_slstm_state(batch, cfg)
    raise ValueError(kind)


def block_state_logical(kind: str):
    if kind == "attn":
        return attn_lib.KV_CACHE_LOGICAL
    if kind == "mamba":
        return ssm_lib.MAMBA_STATE_LOGICAL
    if kind == "mlstm":
        return ssm_lib.MLSTM_STATE_LOGICAL
    if kind == "slstm":
        return ssm_lib.SLSTM_STATE_LOGICAL
    raise ValueError(kind)


def apply_block(p, x, cfg: ModelConfig, kind: str, use_moe: bool, *,
                mode: str, layer_idx: int, positions, state=None, index=None,
                attn_impl: str = "xla", cache_capacity=None):
    """Returns (x, new_state, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["norm1"], x, cfg.norm_eps, cfg.norm_kind)

    if kind == "attn":
        window = attn_lib.layer_window(cfg, layer_idx)
        if mode == "decode":
            y, new_state = attn_lib.attend_decode(
                p["mix"], h, state, index, cfg, positions, window)
        else:
            y, kv = attn_lib.attend_full(
                p["mix"], h, cfg, positions, window, impl=attn_impl)
            new_state = state
            if mode == "prefill":
                new_state = attn_lib.prefill_cache_from_kv(
                    kv[0], kv[1], window, cfg.jnp_dtype,
                    capacity=cache_capacity)
    elif kind == "mamba":
        if mode == "decode":
            y, new_state = ssm_lib.mamba_step(p["mix"], h, state, cfg)
        else:
            y, new_state = ssm_lib.mamba_full(p["mix"], h, cfg, state=None)
    elif kind == "mlstm":
        if mode == "decode":
            y, new_state = ssm_lib.mlstm_step(p["mix"], h, state, cfg)
        else:
            y, new_state = ssm_lib.mlstm_full(p["mix"], h, cfg, state=None)
    elif kind == "slstm":
        if mode == "decode":
            y, new_state = ssm_lib.slstm_step(p["mix"], h, state, cfg)
        else:
            y, new_state = ssm_lib.slstm_full(p["mix"], h, cfg, state=None)
    else:
        raise ValueError(kind)

    x = shard_act(x + y.astype(x.dtype), ("batch", "seq", "act_embed"))

    if _has_mlp(cfg):
        h2 = apply_norm(p["norm2"], x, cfg.norm_eps, cfg.norm_kind)
        if use_moe:
            y2, aux = apply_moe(p["mlp"], h2, cfg)
        else:
            y2 = apply_mlp(p["mlp"], h2, cfg.act)
        x = shard_act(x + y2.astype(x.dtype), ("batch", "seq", "act_embed"))

    if mode in ("train", "encode"):
        new_state = None
    return x, new_state, aux


# --------------------------------------------------------------- units

def init_unit(key, cfg: ModelConfig, dtype):
    """One repeating unit: dict 'l{j}' -> block params."""
    pat, moes = cfg.layer_pattern, cfg.moe_pattern
    ks = jax.random.split(key, len(pat))
    p, l = {}, {}
    for j, kind in enumerate(pat):
        p[f"l{j}"], l[f"l{j}"] = init_block(ks[j], cfg, kind, moes[j], dtype)
    return p, l


def init_unit_state(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    return {f"l{j}": init_block_state(cfg, kind, batch, cache_len, dtype,
                                      layer_idx=j)
            for j, kind in enumerate(cfg.layer_pattern)}


def unit_state_logical(cfg: ModelConfig):
    return {f"l{j}": block_state_logical(kind)
            for j, kind in enumerate(cfg.layer_pattern)}


def apply_unit(p, x, cfg: ModelConfig, *, unit_base_layer, mode, positions,
               state=None, index=None, attn_impl="xla", cache_capacity=None):
    """Apply every block in one unit sequentially."""
    aux_total = jnp.zeros((), jnp.float32)
    new_state = {}
    for j, kind in enumerate(cfg.layer_pattern):
        st = state[f"l{j}"] if state is not None else None
        x, st2, aux = apply_block(
            p[f"l{j}"], x, cfg, kind, cfg.moe_pattern[j],
            mode=mode, layer_idx=unit_base_layer + j, positions=positions,
            state=st, index=index, attn_impl=attn_impl,
            cache_capacity=cache_capacity)
        new_state[f"l{j}"] = st2
        aux_total = aux_total + aux
    if mode in ("train", "encode"):
        new_state = None
    return x, new_state, aux_total
