"""State-space / recurrent cells: Mamba (selective SSM), mLSTM and sLSTM.

All cells expose three entry points:
  init_<cell>(key, cfg, dtype)            -> (params, logical)
  <cell>_full(p, x, cfg, state=None)      -> (y, final_state)   train/prefill
  <cell>_step(p, x1, state, cfg)          -> (y1, state)        decode

Full-sequence paths use a chunked scan (outer lax.scan over chunks carrying
the recurrent state, inner computation checkpointed) — the TPU-native
replacement for the fused recompute-in-backward CUDA kernels of the Mamba/
xLSTM papers (see scan_utils).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import _init
from repro.models.scan_utils import chunked_scan, pick_chunk
from repro.sharding.context import shard_act


# =================================================================== mamba

def _mamba_dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    dt_rank = max(1, math.ceil(cfg.d_model / 16))
    return di, s.d_state, s.d_conv, dt_rank


def init_mamba(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di, ds, dc, dtr = _mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": _init(ks[0], (d, 2 * di), 1 / math.sqrt(d), dtype),
        "conv_w": _init(ks[1], (dc, di), 1 / math.sqrt(dc), dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _init(ks[2], (di, dtr + 2 * ds), 1 / math.sqrt(di), dtype),
        "dt_w": _init(ks[3], (dtr, di), 1 / math.sqrt(dtr), dtype),
        "dt_b": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))).astype(jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _init(ks[5], (di, d), 1 / math.sqrt(di), dtype),
    }
    l = {
        "in_proj": ("embed", "inner"),
        "conv_w": ("conv", "inner"),
        "conv_b": ("inner",),
        "x_proj": ("inner", "state"),
        "dt_w": ("state", "inner"),
        "dt_b": ("inner",),
        "A_log": ("inner", "state"),
        "D": ("inner",),
        "out_proj": ("inner", "embed"),
    }
    return p, l


def init_mamba_state(batch, cfg: ModelConfig, dtype):
    di, ds, dc, _ = _mamba_dims(cfg)
    return {
        "h": jnp.zeros((batch, di, ds), jnp.float32),
        "conv": jnp.zeros((batch, dc - 1, di), dtype),
    }


MAMBA_STATE_LOGICAL = {"h": ("batch", "inner", "state"),
                       "conv": ("batch", "conv", "inner")}


def _mamba_inner(p, xs_conv, dt, Bm, Cm, h0):
    """Selective-scan over one chunk.

    xs_conv: (B,T,di) post-conv activations; dt: (B,T,di); Bm/Cm: (B,T,ds);
    h0: (B,di,ds).  Returns (y (B,T,di), hT).
    """
    A = -jnp.exp(p["A_log"])                                   # (di, ds)
    dA = jnp.exp(dt[..., None] * A)                            # (B,T,di,ds)
    dBx = (dt * xs_conv)[..., None] * Bm[:, :, None, :]        # (B,T,di,ds)

    def combine(l, r):
        a_l, b_l = l
        a_r, b_r = r
        return a_l * a_r, a_r * b_l + b_r

    a_cum, b_cum = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    h = a_cum * h0[:, None] + b_cum                            # (B,T,di,ds)
    y = jnp.einsum("btds,bts->btd", h, Cm)
    y = y + p["D"] * xs_conv
    return y, h[:, -1]


def _mamba_preproj(p, x, cfg):
    di, ds, dc, dtr = _mamba_dims(cfg)
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    return shard_act(xs, ("batch", "seq", "inner")), shard_act(z, ("batch", "seq", "inner"))


def _mamba_postconv(p, xc, cfg):
    """xc: conv output (B,T,di). Returns dt, Bm, Cm (f32)."""
    di, ds, dc, dtr = _mamba_dims(cfg)
    dbc = jnp.einsum("btd,de->bte", xc, p["x_proj"]).astype(jnp.float32)
    dt_in, Bm, Cm = jnp.split(dbc, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("btr,rd->btd", dt_in, p["dt_w"]) + p["dt_b"])
    return dt, Bm, Cm


def _causal_conv(p, xs, prev, dc):
    """xs: (B,T,di); prev: (B,dc-1,di) left context. Returns (out, new_prev)."""
    ext = jnp.concatenate([prev.astype(xs.dtype), xs], axis=1)  # (B, T+dc-1, di)
    out = sum(ext[:, i:i + xs.shape[1]] * p["conv_w"][i] for i in range(dc))
    out = jax.nn.silu(out + p["conv_b"])
    new_prev = ext[:, -(dc - 1):] if dc > 1 else prev
    return out, new_prev


def mamba_full(p, x, cfg: ModelConfig, state=None, chunk=256):
    B, T, _ = x.shape
    di, ds, dc, _ = _mamba_dims(cfg)
    if state is None:
        state = init_mamba_state(B, cfg, x.dtype)
    xs, z = _mamba_preproj(p, x, cfg)
    ck = pick_chunk(T, chunk)

    def step(st, xs_chunk):
        xc, new_conv = _causal_conv(p, xs_chunk, st["conv"], dc)
        dt, Bm, Cm = _mamba_postconv(p, xc, cfg)
        y, hT = _mamba_inner(p, xc.astype(jnp.float32), dt, Bm, Cm, st["h"])
        return {"h": hT, "conv": new_conv}, y

    state, y = chunked_scan(step, state, xs, seq_axis=1, chunk=ck)
    out = y.astype(x.dtype) * jax.nn.silu(z)
    return jnp.einsum("btd,de->bte", out, p["out_proj"]), state


def mamba_step(p, x1, state, cfg: ModelConfig):
    """x1: (B,1,d)."""
    di, ds, dc, _ = _mamba_dims(cfg)
    xs, z = _mamba_preproj(p, x1, cfg)
    xc, new_conv = _causal_conv(p, xs, state["conv"], dc)
    dt, Bm, Cm = _mamba_postconv(p, xc, cfg)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[:, 0, :, None] * A)                       # (B,di,ds)
    dBx = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0, None, :]
    h = dA * state["h"] + dBx
    y = jnp.einsum("bds,bs->bd", h, Cm[:, 0]) + p["D"] * xc[:, 0].astype(jnp.float32)
    out = y[:, None].astype(x1.dtype) * jax.nn.silu(z)
    return jnp.einsum("btd,de->bte", out, p["out_proj"]), {"h": h, "conv": new_conv}


# =================================================================== mLSTM

def _mlstm_dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    H = s.num_heads
    dh = di // H
    return di, H, dh


def init_mlstm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di, H, dh = _mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    s = 1 / math.sqrt(d)
    si = 1 / math.sqrt(di)
    p = {
        "in_proj": _init(ks[0], (d, 2 * di), s, dtype),       # main + output gate
        "wq": _init(ks[1], (di, H, dh), si, dtype),
        "wk": _init(ks[2], (di, H, dh), si, dtype),
        "wv": _init(ks[3], (di, H, dh), si, dtype),
        "w_if": _init(ks[4], (di, 2 * H), si, jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]).astype(jnp.float32),
        "out_norm": jnp.ones((H, dh), jnp.float32),
        "out_proj": _init(ks[6], (di, d), si, dtype),
    }
    l = {
        "in_proj": ("embed", "inner"),
        "wq": ("inner", "heads", "head_dim"),
        "wk": ("inner", "heads", "head_dim"),
        "wv": ("inner", "heads", "head_dim"),
        "w_if": ("inner", "heads"),
        "b_if": ("heads",),
        "out_norm": ("heads", "head_dim"),
        "out_proj": ("inner", "embed"),
    }
    return p, l


def init_mlstm_state(batch, cfg: ModelConfig):
    di, H, dh = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
    }


MLSTM_STATE_LOGICAL = {"C": ("batch", "heads", "head_dim", "head_dim"),
                       "n": ("batch", "heads", "head_dim"),
                       "m": ("batch", "heads")}


def _mlstm_gates_qkv(p, x, cfg):
    u = jnp.einsum("btd,de->bte", x, p["in_proj"])
    main, og = jnp.split(u, 2, axis=-1)
    q = jnp.einsum("bti,ihk->bthk", main, p["wq"])
    k = jnp.einsum("bti,ihk->bthk", main, p["wk"])
    v = jnp.einsum("bti,ihk->bthk", main, p["wv"])
    gif = jnp.einsum("bti,ih->bth", main.astype(jnp.float32), p["w_if"]) + p["b_if"]
    i_pre, f_pre = jnp.split(gif, 2, axis=-1)                 # (B,T,H)
    return q, k, v, i_pre, f_pre, og


def _mlstm_cell_seq(q, k, v, i_pre, f_pre, st):
    """Sequential (within-chunk) stabilized mLSTM recurrence.

    q/k/v: (B,T,H,dh) f32; i_pre/f_pre: (B,T,H). Returns (h (B,T,H,dh), st).
    """
    dh = q.shape[-1]
    scale = 1.0 / math.sqrt(dh)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, ft = xs
        logf = jax.nn.log_sigmoid(ft)                         # (B,H)
        m_new = jnp.maximum(logf + m, it)
        f_act = jnp.exp(logf + m - m_new)[..., None, None]
        i_act = jnp.exp(it - m_new)[..., None, None]
        C = f_act * C + i_act * (kt[..., :, None] * vt[..., None, :])
        n = f_act[..., 0] * n + i_act[..., 0] * kt
        qs = qt * scale
        num = jnp.einsum("bhkv,bhk->bhv", C, qs)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qs))
        den = jnp.maximum(den, jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, i_pre, f_pre))
    (C, n, m), h = jax.lax.scan(step, (st["C"], st["n"], st["m"]), xs)
    return jnp.moveaxis(h, 0, 1), {"C": C, "n": n, "m": m}


def _mlstm_cell_chunkwise(q, k, v, i_pre, f_pre, st, chunk=64):
    """Chunkwise-parallel mLSTM (same closed form as the Pallas kernel):
    the matrix memory C is updated once per chunk instead of per timestep,
    turning the inner sums into (L,L)x(L,dh) MXU matmuls and cutting the
    HBM round-trips of C by the chunk length.

    q/k/v: (B,T,H,dh) f32; i/f: (B,T,H). Returns (h, state).
    """
    B, T, H, dh = q.shape
    L = pick_chunk(T, chunk)
    scale = 1.0 / math.sqrt(dh)
    qs = q * scale

    def step(carry, xs):
        C, n, m = carry                                   # (B,H,dh,dh) ...
        qc, kc, vc, ic, fc = xs                           # (B,L,H,*)
        lf = jax.nn.log_sigmoid(fc)                       # (B,L,H)
        F = jnp.cumsum(lf, axis=1)
        g = jax.lax.cummax(ic - F, axis=1)
        m_t = F + jnp.maximum(m[:, None], g)              # (B,L,H)

        w_inter = jnp.exp(F + m[:, None] - m_t)           # (B,L,H)
        qC = jnp.einsum("blhk,bhkv->blhv", qc, C)
        num = w_inter[..., None] * qC
        den = w_inter * jnp.einsum("blhk,bhk->blh", qc, n)

        logw = (F - m_t)[:, :, None] + (ic - F)[:, None]  # (B,Lq,Ls,H)
        t_idx = jnp.arange(L)
        mask = t_idx[None, :, None, None] >= t_idx[None, None, :, None]
        W = jnp.where(mask, jnp.exp(logw), 0.0)
        S = jnp.einsum("blhk,bshk->blsh", qc, kc)
        WS = W * S
        num = num + jnp.einsum("blsh,bshv->blhv", WS, vc)
        den = den + WS.sum(axis=2)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

        m_last = m_t[:, -1]                               # (B,H)
        w_state = jnp.exp((F[:, -1:] - F) + ic - m_last[:, None])
        decay = jnp.exp(F[:, -1] + m - m_last)
        C2 = decay[..., None, None] * C + jnp.einsum(
            "bshk,bshv->bhkv", kc * w_state[..., None], vc)
        n2 = decay[..., None] * n + (kc * w_state[..., None]).sum(1)
        return (C2, n2, m_last), h

    def to_chunks(a):
        return jnp.moveaxis(
            a.reshape((B, T // L, L) + a.shape[2:]), 1, 0)

    xs = tuple(to_chunks(a) for a in (qs, k, v, i_pre, f_pre))
    (C, n, m), hs = jax.lax.scan(step, (st["C"], st["n"], st["m"]), xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, T, H, dh)
    return h, {"C": C, "n": n, "m": m}


# module-level default so the perf hillclimb can switch the algorithm
# without re-threading an argument through every block signature.
# "chunkwise" adopted after the §Perf hillclimb: matches the sequential
# oracle to ~1e-7 and cuts the mLSTM HBM-traffic term ~55x.
MLSTM_DEFAULT_IMPL = "chunkwise"


def mlstm_full(p, x, cfg: ModelConfig, state=None, chunk=128, impl=None):
    impl = impl or MLSTM_DEFAULT_IMPL
    B, T, _ = x.shape
    if state is None:
        state = init_mlstm_state(B, cfg)
    q, k, v, i_pre, f_pre, og = _mlstm_gates_qkv(p, x, cfg)
    qf, kf, vf = (a.astype(jnp.float32) for a in (q, k, v))
    if impl == "pallas":
        from repro.kernels.mlstm_scan import ops as mls_ops
        h, state = mls_ops.mlstm_chunkwise(qf, kf, vf, i_pre, f_pre, state)
    elif impl == "chunkwise":
        h, state = _mlstm_cell_chunkwise(qf, kf, vf, i_pre, f_pre, state,
                                         chunk=min(chunk, 64))
    else:
        ck = pick_chunk(T, chunk)

        def step(st, xs):
            return tuple(
                reversed(_mlstm_cell_seq(xs[0], xs[1], xs[2], xs[3], xs[4], st)))

        state, h = chunked_scan(step, state, (qf, kf, vf, i_pre, f_pre),
                                seq_axis=1, chunk=ck)
    h = h * p["out_norm"]                                      # per-head scale
    di, H, dh = _mlstm_dims(cfg)
    h = h.reshape(B, T, di).astype(x.dtype) * jax.nn.silu(og)
    return jnp.einsum("bti,id->btd", h, p["out_proj"]), state


def mlstm_step(p, x1, state, cfg: ModelConfig):
    q, k, v, i_pre, f_pre, og = _mlstm_gates_qkv(p, x1, cfg)
    h, state = _mlstm_cell_seq(q.astype(jnp.float32), k.astype(jnp.float32),
                               v.astype(jnp.float32), i_pre, f_pre, state)
    h = h * p["out_norm"]
    B = x1.shape[0]
    di, H, dh = _mlstm_dims(cfg)
    h = h.reshape(B, 1, di).astype(x1.dtype) * jax.nn.silu(og)
    return jnp.einsum("bti,id->btd", h, p["out_proj"]), state


# =================================================================== sLSTM

def _slstm_dims(cfg: ModelConfig):
    H = cfg.ssm.num_heads
    dh = cfg.d_model // H
    return H, dh


def init_slstm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    H, dh = _slstm_dims(cfg)
    ks = jax.random.split(key, 3)
    p = {
        "w_x": _init(ks[0], (d, 4 * d), 1 / math.sqrt(d), dtype),   # z i f o
        "r_h": _init(ks[1], (4, H, dh, dh), 1 / math.sqrt(dh), jnp.float32),
        "b": jnp.concatenate([jnp.zeros((2 * d,)), 3.0 * jnp.ones((d,)),
                              jnp.zeros((d,))]).astype(jnp.float32),
        "out_proj": _init(ks[2], (d, d), 1 / math.sqrt(d), dtype),
    }
    l = {"w_x": ("embed", "inner"), "r_h": ("conv", "heads", "head_dim", "head_dim"),
         "b": ("inner",), "out_proj": ("embed", "embed")}
    return p, l


def init_slstm_state(batch, cfg: ModelConfig):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"h": z, "c": z, "n": jnp.ones((batch, d), jnp.float32),
            "m": jnp.zeros((batch, d), jnp.float32)}


SLSTM_STATE_LOGICAL = {k: ("batch", "inner") for k in ("h", "c", "n", "m")}


def _slstm_cell_seq(p, wx, st, cfg):
    """wx: (B,T,4d) precomputed input projections."""
    H, dh = _slstm_dims(cfg)
    d = H * dh

    def step(carry, xt):
        h, c, n, m = carry
        hh = h.reshape(-1, H, dh)
        rec = jnp.einsum("ghkl,bhk->gbhl", p["r_h"], hh).reshape(4, -1, d)
        pre = xt + p["b"] + jnp.concatenate([rec[0], rec[1], rec[2], rec[3]], -1)
        z_pre, i_pre, f_pre, o_pre = jnp.split(pre, 4, axis=-1)
        z = jnp.tanh(z_pre)
        o = jax.nn.sigmoid(o_pre)
        logf = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(logf + m, i_pre)
        i_act = jnp.exp(i_pre - m_new)
        f_act = jnp.exp(logf + m - m_new)
        c = f_act * c + i_act * z
        n = f_act * n + i_act
        h = o * (c / jnp.maximum(n, 1e-6))
        return (h, c, n, m_new), h

    xs = jnp.moveaxis(wx, 1, 0).astype(jnp.float32)
    (h, c, n, m), hs = jax.lax.scan(step, (st["h"], st["c"], st["n"], st["m"]), xs)
    return jnp.moveaxis(hs, 0, 1), {"h": h, "c": c, "n": n, "m": m}


def slstm_full(p, x, cfg: ModelConfig, state=None, chunk=128):
    B, T, _ = x.shape
    if state is None:
        state = init_slstm_state(B, cfg)
    wx = jnp.einsum("btd,de->bte", x, p["w_x"])
    ck = pick_chunk(T, chunk)

    def step(st, wx_chunk):
        hs, st2 = _slstm_cell_seq(p, wx_chunk, st, cfg)
        return st2, hs

    state, hs = chunked_scan(step, state, wx, seq_axis=1, chunk=ck)
    return jnp.einsum("btd,de->bte", hs.astype(x.dtype), p["out_proj"]), state


def slstm_step(p, x1, state, cfg: ModelConfig):
    wx = jnp.einsum("btd,de->bte", x1, p["w_x"])
    hs, state = _slstm_cell_seq(p, wx, state, cfg)
    return jnp.einsum("btd,de->bte", hs.astype(x1.dtype), p["out_proj"]), state
