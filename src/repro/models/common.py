"""Model configuration dataclasses shared by every architecture family."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    d_ff_expert: int = 0          # per-expert hidden; 0 -> use model d_ff
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba"           # "mamba" | "mlstm" | "slstm"
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    num_heads: int = 4            # for m/sLSTM


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    rope_theta: float = 10000.0
    use_mrope: bool = False       # Qwen2-VL multimodal RoPE (3 sections)
    mrope_sections: tuple = (16, 24, 24)
    sliding_window: int = 0       # 0 = full attention
    # pattern of window use per layer: "all_global", "all_local",
    # or "gemma" (5 local : 1 global) / "starcoder_swa"
    window_pattern: str = "all_global"
    global_every: int = 6         # for "gemma": layer % 6 == 5 is global
    qkv_bias: bool = False
    causal: bool = True
    softcap: float = 0.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    attn: AttnConfig = AttnConfig()
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # layer_pattern: per-layer block kinds within one repeating unit; the
    # model scans over units.  e.g. jamba: ("mamba","mamba","mamba","attn",
    # "mamba","mamba","mamba","mamba") with moe_pattern marking MoE MLPs.
    layer_pattern: tuple = ("attn",)
    moe_pattern: tuple = (False,)  # same length as layer_pattern
    is_encoder: bool = False       # bidirectional, MLM-style (hubert)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    norm_kind: str = "rmsnorm"     # rmsnorm | layernorm
    embed_scale: bool = False      # multiply embeddings by sqrt(d_model)
    act: str = "silu"              # silu (swiglu) | gelu (plain mlp)
    dtype: str = "bfloat16"
    # modality frontend stub: tokens are precomputed embeddings, not ids
    embed_inputs: bool = True      # False -> input is (B, S, d_model) floats
    max_seq_len: int = 131072
    # citation / library metadata used by Tryage constraint functions
    source: str = ""
    param_count_hint: float = 0.0  # filled by registry with exact count

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def num_units(self) -> int:
        assert self.num_layers % len(self.layer_pattern) == 0, (
            f"{self.name}: {self.num_layers} layers not divisible by "
            f"unit of {len(self.layer_pattern)}"
        )
        return self.num_layers // len(self.layer_pattern)

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    def reduced(self, num_layers=2, d_model=256, max_experts=4) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        unit = len(self.layer_pattern)
        layers = max(num_layers, unit)
        layers -= layers % unit
        heads = max(1, min(self.num_heads, 4))
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        d_model = min(d_model, 512)
        moe = None
        if self.moe is not None:
            ne = min(self.moe.num_experts, max_experts)
            moe = dataclasses.replace(
                self.moe,
                num_experts=ne,
                top_k=min(self.moe.top_k, ne),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                d_ff_expert=(d_model * 2 if self.moe.d_ff_expert else 0),
            )
        ssm = self.ssm
        if ssm is not None:
            ssm = dataclasses.replace(ssm, num_heads=min(ssm.num_heads, 2))
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=0,
            d_ff=d_model * 3,
            vocab_size=min(self.vocab_size, 512),
            moe=moe,
            ssm=ssm,
            dtype="float32",
            max_seq_len=2048,
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the assigned benchmark input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
