from repro.models.common import (AttnConfig, InputShape, INPUT_SHAPES,
                                 ModelConfig, MoEConfig, SSMConfig)
from repro.models.model import (count_params, cross_entropy, decode_step,
                                forward, init_decode_state, init_model,
                                lm_loss, prefill, decode_state_logical)

__all__ = [
    "AttnConfig", "InputShape", "INPUT_SHAPES", "ModelConfig", "MoEConfig",
    "SSMConfig", "count_params", "cross_entropy", "decode_step", "forward",
    "init_decode_state", "init_model", "lm_loss", "prefill",
    "decode_state_logical",
]
