"""Chunked / checkpointed scan helpers (sqrt-T memory trick).

TPU adaptation note: Mamba/xLSTM GPU kernels avoid materializing the
recurrent state for every timestep by recomputing it in the backward pass
inside a fused CUDA kernel.  The JAX/TPU-native equivalent is a chunked
scan: an outer ``lax.scan`` over chunks carries only chunk-boundary states,
and the inner per-chunk computation is wrapped in ``jax.checkpoint`` so its
intermediates are rematerialized during the backward pass.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def chunked_scan(step_chunk, init_state, xs, seq_axis: int, chunk: int):
    """Scan ``step_chunk(state, x_chunk) -> (state, y_chunk)`` over chunks.

    ``xs`` is a pytree whose leaves share ``seq_axis`` of length T; T must be
    divisible by ``chunk``.  Each chunk application is checkpointed.
    """
    T = jax.tree.leaves(xs)[0].shape[seq_axis]
    assert T % chunk == 0, (T, chunk)
    n = T // chunk

    def to_chunks(a):
        shape = a.shape
        new = shape[:seq_axis] + (n, chunk) + shape[seq_axis + 1:]
        return jnp.moveaxis(a.reshape(new), seq_axis, 0)

    xs_c = jax.tree.map(to_chunks, xs)

    body = jax.checkpoint(lambda s, x: step_chunk(s, x))

    state, ys_c = jax.lax.scan(body, init_state, xs_c)

    def from_chunks(a):
        a = jnp.moveaxis(a, 0, seq_axis)  # (..., n, chunk, ...)
        shape = a.shape
        return a.reshape(shape[:seq_axis] + (T,) + shape[seq_axis + 2:])

    return state, jax.tree.map(from_chunks, ys_c)


def pick_chunk(T: int, target: int = 256) -> int:
    """Largest divisor of T that is <= target (>=1)."""
    c = min(target, T)
    while T % c:
        c -= 1
    return c
