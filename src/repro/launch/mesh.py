"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state.  Single pod = 16x16 (256 chips, TPU v5e); multi-pod adds
a leading "pod" axis (2 pods = 512 chips), over which only the batch /
fsdp dimensions shard (the pod axis crosses DCN, so we keep per-layer
tensor collectives off it).

Every builder validates the requested shape against the devices that are
actually visible *before* handing the shape to XLA, because
``jax.make_mesh`` on an undersized host raises an opaque reshape error
deep inside device assignment.  The validation error names the CPU
escape hatch (``XLA_FLAGS=--xla_force_host_platform_device_count=N``)
so a failing dry-run or test tells the operator exactly how to proceed.
"""

from __future__ import annotations

import math

import jax


def _require_devices(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Fail fast, and usefully, when the host cannot back the mesh."""
    need = math.prod(shape)
    have = jax.device_count()
    if need > have:
        raise ValueError(
            f"mesh {dict(zip(axes, shape))} needs {need} devices but only "
            f"{have} {'is' if have == 1 else 'are'} visible. On CPU, "
            f"relaunch with XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={need} (set before jax is imported) to simulate the "
            f"mesh, or shrink the requested shape.")


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    _require_devices(shape, axes)
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small ``(data, model)`` mesh over whatever devices exist (CPU
    tests, the serving engine's ``--mesh`` flag)."""
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got "
                         f"data={data} model={model}")
    shape, axes = (data, model), ("data", "model")
    _require_devices(shape, axes)
    return jax.make_mesh(shape, axes)
