"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state.  Single pod = 16x16 (256 chips, TPU v5e); multi-pod adds
a leading "pod" axis (2 pods = 512 chips), over which only the batch /
fsdp dimensions shard (the pod axis crosses DCN, so we keep per-layer
tensor collectives off it).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests)."""
    return jax.make_mesh((data, model), ("data", "model"))
