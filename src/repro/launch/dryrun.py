import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production mesh, record memory/cost/collective stats for §Roofline.

MUST be run as its own process (the XLA_FLAGS line above executes before
any jax import and forces 512 host devices).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import get_config, list_archs
from repro.launch import hlo_stats
from repro.launch.hlo_loops import loop_aware_totals
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import Roofline, active_params, model_flops
from repro.launch.steps import PerfKnobs, build_step
from repro.models.common import INPUT_SHAPES
from repro.launch.specs import applicable

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# Per-(arch, shape) perf-knob overrides discovered during §Perf
# (see scripts/hillclimb.py and EXPERIMENTS.md §Perf for the full log).
KNOB_OVERRIDES: dict[tuple, PerfKnobs] = {
    # h4_pure_tp: decode wants 256-way TP (weights never move; psum small
    # activations) instead of FSDP re-gathers every token.
    ("jamba-v0.1-52b", "decode_32k"): PerfKnobs(rule_overrides={
        "embed": None, "mlp": ("model", "data"),
        "heads": ("model", "data"), "kv_heads": ("model", "data"),
        "inner": ("model", "data"), "vocab": ("model", "data"),
        "capacity": None}),
    # h2_kvheads_nofsdp: shard kv_heads (16 == mesh axis) instead of the
    # cache seq dim; replicate 1GB of weights over 'data'.
    ("qwen1.5-0.5b", "decode_32k"): PerfKnobs(rule_overrides={
        "cache": None, "embed": None}),
    # same pattern transfers to qwen2-moe (also 16 kv heads):
    # t_mem -60%, t_coll -97%, peak 14.98GiB (fits)
    ("qwen2-moe-a2.7b", "decode_32k"): PerfKnobs(rule_overrides={
        "cache": None, "embed": None}),
}


def knobs_for(arch: str, shape: str) -> PerfKnobs:
    if (arch, shape) in KNOB_OVERRIDES:
        return KNOB_OVERRIDES[(arch, shape)]
    cfg = get_config(arch)
    arch = cfg.name  # canonical hyphen form
    if shape == "train_4k":
        # grad accumulation + grouped remat sized so train fits ~16GB HBM
        if arch == "qwen2-vl-72b":
            return PerfKnobs(microbatch=8, moment_dtype="bfloat16",
                             unit_group=4)
        if arch == "grok-1-314b":
            return PerfKnobs(microbatch=8, moment_dtype="bfloat16",
                             unit_group=4)
        if arch == "jamba-v0.1-52b":
            return PerfKnobs(microbatch=8, moment_dtype="bfloat16")
        if arch == "starcoder2-15b":
            return PerfKnobs(microbatch=4, unit_group=2)
        if arch == "gemma3-4b":
            return PerfKnobs(microbatch=8)
        if arch == "xlstm-1.3b":
            # mb8 shrinks chunkwise-mLSTM peak under the 16GB HBM budget
            return PerfKnobs(microbatch=8, unit_group=2)
        if arch in ("tinyllama-1.1b", "hubert-xlarge"):
            return PerfKnobs(microbatch=2)
        if arch == "qwen2-moe-a2.7b":
            return PerfKnobs(microbatch=4)
    return PerfKnobs()


def run_one(arch: str, shape_name: str, mesh_kind: str,
            knobs: PerfKnobs | None = None, save: bool = True,
            tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag}
    if not ok:
        rec.update(status="SKIP", reason=reason)
        if save:
            _save(rec)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    knobs = knobs or knobs_for(arch, shape_name)
    t0 = time.time()
    try:
        built = build_step(cfg, shape, mesh, knobs)
        with mesh:
            lowered = built.fn.lower(*built.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            # older jax returns a one-element list of dicts per device
            if isinstance(cost, (list, tuple)):
                cost = cost[0] if cost else {}
            hlo = compiled.as_text()
    except Exception as e:  # noqa: BLE001 — record the failure verbatim
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
        if save:
            _save(rec)
        return rec

    coll = hlo_stats.collective_stats(hlo)
    # loop-aware accounting: cost_analysis() counts while bodies once,
    # which undercounts scan-over-layers models by ~num_layers.
    la = loop_aware_totals(hlo)
    rl = Roofline(flops=la["dot_flops"], hbm_bytes=la["traffic_bytes"],
                  collective_bytes=la["collective_bytes"])

    n_chips = mesh.devices.size
    # exact param count from the abstract params (arg 0 of every step)
    total_params = sum(
        int(x.size) for x in jax.tree.leaves(built.args[0]))
    act = active_params(cfg, total_params)
    mf = model_flops(cfg, shape, act)

    rec.update(
        status="OK",
        knobs={"microbatch": knobs.microbatch,
               "moment_dtype": knobs.moment_dtype, "remat": knobs.remat,
               "attn_impl": knobs.attn_impl, "unit_group": knobs.unit_group,
               "rule_overrides": knobs.rule_overrides},
        n_chips=n_chips,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        total_params=total_params,
        active_params=int(act),
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes),
        },
        cost={k: float(v) for k, v in cost.items()
              if isinstance(v, (int, float))},
        loop_aware=la,
        collectives=coll,
        roofline=rl.as_dict(),
        model_flops_global=mf,
        model_flops_per_chip=mf / n_chips,
        useful_flops_frac=((mf / n_chips) / la["dot_flops"]
                           if la["dot_flops"] else None),
    )
    if save:
        _save(rec)
    return rec


def _save(rec):
    os.makedirs(OUT_DIR, exist_ok=True)
    tag = f"_{rec['tag']}" if rec.get("tag") else ""
    path = os.path.join(
        OUT_DIR, f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    if args.arch:
        from repro.configs import _ALIASES
        archs = [a if a in _ALIASES else a for a in archs]

    results = []
    for a in archs:
        for s in shapes:
            t0 = time.time()
            rec = run_one(a, s, args.mesh, tag=args.tag)
            dt = time.time() - t0
            status = rec["status"]
            extra = ""
            if status == "OK":
                extra = (f"dom={rec['roofline']['dominant']} "
                         f"peak={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
                         f"compile={rec['compile_s']}s")
            elif status == "FAIL":
                extra = rec["error"][:160]
            else:
                extra = rec["reason"][:90]
            print(f"[{status:4s}] {a:18s} {s:12s} {args.mesh:8s} "
                  f"({dt:6.1f}s) {extra}", flush=True)
            results.append(rec)
    n_ok = sum(r["status"] == "OK" for r in results)
    n_skip = sum(r["status"] == "SKIP" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"done: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
