"""Loop-aware HLO cost accounting.

``compiled.cost_analysis()`` counts each while-loop body ONCE, so any
scan-over-layers model is undercounted by ~num_layers.  This module parses
the post-optimization HLO text, builds the computation call graph, infers
while-loop trip counts from their condition computations, and accumulates

  * dot FLOPs           (2 x prod(out_dims) x contracted_size)
  * collective bytes    (output bytes of all-gather/all-reduce/...)
  * memory traffic      (2 x output bytes of instructions whose result is
                         >= 16 KiB — smaller results are VMEM/VREG-resident
                         on the TPU target — plus dot operand bytes, which
                         captures per-iteration weight reads)

with each while body weighted by its trip count.  Validated against an
unrolled-vs-scanned equivalence test (tests/test_hlo_loops.py).
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*\)\s*->.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLED = re.compile(
    r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_WHILE = re.compile(r"\bwhile\(")
_CONST_INT = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota"}

_OP_RE = re.compile(
    r"^(?:\([^)]*\)|[\w\[\]{},\s*/]+?)\s+([a-z][a-z0-9\-]*)\(")


def _shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * b
    return total


def _first_shape(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None, None
    dt, dims = m.groups()
    shape = [int(d) for d in dims.split(",")] if dims else []
    return dt, shape


TRAFFIC_MIN_BYTES = 16 * 1024


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    coll_bytes: float = 0.0
    traffic_bytes: float = 0.0
    calls: list = dataclasses.field(default_factory=list)  # (name, kind)
    whiles: list = dataclasses.field(default_factory=list)  # (body, cond)


def _split_operands(args: str) -> list:
    """Split an operand list on top-level commas only — shapes like
    ``f32[4,8]{1,0}`` carry commas inside brackets/braces."""
    out, cur, depth = [], [], 0
    for ch in args:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return out


def _operand_dims(operand: str, symbols: dict):
    """Dims of one operand: inline shape ('f32[4,8]{1,0} %x') if present,
    else symbol-table lookup of the bare name ('%x')."""
    m = _SHAPE_RE.search(operand)
    if m:
        return [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return symbols.get(operand.split()[-1].lstrip("%"))


def _parse_dot_flops(rhs: str, symbols: dict) -> float:
    """rhs: '<out type> dot(<operands>), ..., lhs_contracting_dims={..}'.

    Operands carry inline shapes (newer XLA text) or are bare names
    resolved via the symbol table.
    """
    out_dt, out_shape = _first_shape(rhs)
    if out_shape is None:
        return 0.0
    m = re.search(r"dot\((.*?)\)", rhs)
    if not m:
        return 0.0
    operands = _split_operands(m.group(1))
    lhs_dims = _operand_dims(operands[0], symbols) if operands else None
    if lhs_dims is None:
        return 0.0
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
    contract = 1
    if mc and mc.group(1):
        for d in mc.group(1).split(","):
            if int(d) < len(lhs_dims):
                contract *= lhs_dims[int(d)]
    out_elems = 1
    for d in out_shape:
        out_elems *= d
    return 2.0 * out_elems * contract


def parse_hlo(hlo: str):
    """Returns (comp_stats: dict name->CompStats, cond_trip: dict cond->int,
    entry_name)."""
    # pass 1: symbol table  name -> dims (first shape of the def line)
    symbols: dict[str, list] = {}
    for line in hlo.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            dt, shape = _first_shape(m.group(2))
            if shape is not None:
                symbols[m.group(1)] = shape

    comps: dict[str, CompStats] = {}
    comp_text: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        ls = line.strip()
        # computation header: "[ENTRY] %name (params...) -> type {"
        if ls.endswith("{") and "->" in ls and not ("=" in ls.split("(")[0]):
            tok = ls.split()[1] if ls.startswith("ENTRY") else ls.split()[0]
            cur = tok.lstrip("%")
            comps[cur] = CompStats()
            comp_text[cur] = []
            if ls.startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        if ls == "}":
            cur = None
            continue
        comp_text[cur].append(line)
        m = _INSTR_RE.match(line)
        if not m:
            continue
        rhs = m.group(2)
        op_m = _OP_RE.search(rhs)
        opcode = op_m.group(1) if op_m else ""
        st = comps[cur]
        if opcode == "dot":
            st.dot_flops += _parse_dot_flops(rhs, symbols)
        if opcode.startswith(_COLLECTIVES) and not opcode.endswith("-done"):
            out_part = rhs.split(opcode + "(")[0]
            st.coll_bytes += _shapes_bytes(out_part)
        if _WHILE.search(rhs) and "body=" in rhs:
            body = re.search(r"body=%?([\w.\-]+)", rhs).group(1)
            cond = re.search(r"condition=%?([\w.\-]+)", rhs).group(1)
            trip = None
            tm = re.search(r'known_trip_count[^}]*?"n"\s*:\s*"?(\d+)', rhs)
            if tm:
                trip = int(tm.group(1))
            st.whiles.append((body, cond, trip))
        else:
            for cm in _CALLED.finditer(rhs):
                kind = cm.group(0).split("=")[0]
                if kind in ("calls", "to_apply"):
                    st.calls.append(cm.group(1))
        if opcode and opcode not in _SKIP_OPS and not opcode.startswith(
                "while"):
            out_part = rhs.split(opcode + "(")[0] if (opcode + "(") in rhs else rhs
            ob = _shapes_bytes(out_part)
            if ob >= TRAFFIC_MIN_BYTES:
                st.traffic_bytes += 2.0 * ob
        if opcode == "dot":
            # operand reads (weights re-read every loop iteration)
            m2 = re.search(r"dot\((.*?)\)", rhs)
            if m2:
                for o in _split_operands(m2.group(1)):
                    dims = _operand_dims(o, symbols)
                    if dims:
                        n = 1
                        for d in dims:
                            n *= d
                        st.traffic_bytes += 2.0 * n  # assume bf16

    # fallback trip counts from condition computations (compare-with-const)
    cond_trip: dict[str, int] = {}
    for name, lines in comp_text.items():
        text = "\n".join(lines)
        if "compare" in text or "fusion" in text:
            consts = [int(x) for x in _CONST_INT.findall(text)]
            if consts:
                cond_trip[name] = max(consts)
    return comps, cond_trip, entry


def loop_aware_totals(hlo: str) -> dict:
    comps, cond_trip, entry = parse_hlo(hlo)
    memo: dict[str, tuple] = {}

    def total(name: str, depth=0):
        if name in memo:
            return memo[name]
        st = comps.get(name)
        if st is None or depth > 50:
            return (0.0, 0.0, 0.0)
        f, c, t = st.dot_flops, st.coll_bytes, st.traffic_bytes
        for callee in st.calls:
            cf, cc, ct = total(callee, depth + 1)
            f, c, t = f + cf, c + cc, t + ct
        for body, cond, trip in st.whiles:
            if trip is None:
                trip = cond_trip.get(cond, 1)
            bf, bc, bt = total(body, depth + 1)
            cf, cc, ct = total(cond, depth + 1)
            f += trip * (bf + cf)
            c += trip * (bc + cc)
            t += trip * (bt + ct)
        memo[name] = (f, c, t)
        return memo[name]

    f, c, t = total(entry) if entry else (0.0, 0.0, 0.0)
    return {"dot_flops": f, "collective_bytes": c, "traffic_bytes": t,
            "n_computations": len(comps)}
