"""Parse compiled HLO text for roofline inputs.

``cost_analysis()`` gives FLOPs / bytes-accessed but NOT collective
traffic; we recover it by summing the output-operand sizes of every
collective op in the post-SPMD (per-device) module.  All numbers here are
therefore per-chip.
"""

from __future__ import annotations

import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g. "bf16[16,4096,512]{2,1,0}"
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# start of an HLO instruction: "  %name = <shape-or-tuple> opcode(" — opcode
# may be "all-reduce-start" etc.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.MULTILINE)


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * b
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-collective-kind output bytes + op counts (per device)."""
    out = {k: {"bytes": 0, "count": 0} for k in COLLECTIVES}
    for m in _INSTR_RE.finditer(hlo_text):
        shape_text, kind = m.group(1), m.group(2)
        line = hlo_text[m.start():hlo_text.find("\n", m.start())]
        # async pairs appear as -start/-done; count each logical op once
        if "-done(" in line:
            continue
        out[kind]["bytes"] += _shape_bytes(shape_text)
        out[kind]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def op_histogram(hlo_text: str, top=25) -> dict:
    ops = re.findall(r"=\s*(?:\w+\[[^\]]*\]\S*\s+)+([a-z][\w\-]*)\(", hlo_text)
    hist: dict[str, int] = {}
    for o in ops:
        hist[o] = hist.get(o, 0) + 1
    return dict(sorted(hist.items(), key=lambda kv: -kv[1])[:top])
