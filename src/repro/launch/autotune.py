"""Roofline-driven Pallas tile autotuner.

For each (kernel, batch) point the tuner builds a representative
workload, lowers every tile candidate through jit, parses the compiled
HLO with ``launch.hlo_loops.loop_aware_totals`` and ranks the candidates
by their three-term roofline bound (``launch.roofline.Roofline`` under
the selected ``HWPreset``).  The top-ranked candidates are then
wall-timed (median of ``--repeats`` after a warmup) and the measured
winner is persisted to the tile table consulted by the kernel ops
wrappers (``kernels.tiles``)::

    {"version": 1,
     "<backend>": {"<kernel>": {"<batch>": {"block_b": 256,
                                            "effective_block_b": 256,
                                            "grid": 4,
                                            "modeled_s": ...,
                                            "measured_s": ...}}}}

Every entry records the *effective* tile from the kernel's own
``launch_plan``-style clamp (a requested tile larger than the batch is
silently shrunk), so the table cannot lie about what ran.  Modeled-only
mode (``--no-measure``) skips the timing pass and picks the roofline
winner — deterministic, used by the tests.

Reproduce the checked-in table with::

    python -m repro.launch.autotune --out experiments/tryage/tile_table.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Callable

import numpy as np

from repro.launch.hlo_loops import loop_aware_totals
from repro.launch.roofline import HWPreset, Roofline, resolve_preset


@dataclasses.dataclass
class Candidate:
    """One tile configuration for one (kernel, batch) workload."""

    params: dict                  # tile args the ops wrapper would pass
    record: dict                  # effective-tile info stored alongside
    run: Callable                 # zero-arg timed call (returns arrays)
    lower: Callable               # zero-arg -> compiled HLO text
    modeled_s: float | None = None
    measured_s: float | None = None


def _router_candidates(B: int, rng) -> list[Candidate]:
    import jax
    import jax.numpy as jnp

    from repro.kernels.router_score.kernel import (launch_plan,
                                                   router_score_fused)
    d, hdim, M, n_c = 64, 128, 4, 2
    args = (jnp.asarray(rng.standard_normal((B, d)), jnp.float32),
            jnp.asarray(rng.standard_normal((d, hdim)), jnp.float32),
            jnp.zeros((hdim,), jnp.float32),
            jnp.asarray(rng.standard_normal((hdim, M)), jnp.float32),
            jnp.zeros((M,), jnp.float32),
            jnp.asarray(rng.standard_normal((n_c, M)), jnp.float32),
            jnp.abs(jnp.asarray(rng.standard_normal((B, n_c)),
                                jnp.float32)))
    out, seen = [], set()
    for bb in (32, 64, 128, 256, 512, 1024):
        plan = launch_plan(B, bb)
        if plan["block_b"] in seen:
            continue                  # clamped duplicates tune identically
        seen.add(plan["block_b"])
        out.append(Candidate(
            params={"block_b": bb},
            record={"effective_block_b": plan["block_b"],
                    "grid": plan["grid"]},
            run=(lambda bb=bb: jax.block_until_ready(
                router_score_fused(*args, block_b=bb))),
            lower=(lambda bb=bb: router_score_fused
                   .lower(*args, block_b=bb).compile().as_text())))
    return out


def _flash_candidates(B: int, rng) -> list[Candidate]:
    import jax

    from repro.kernels.flash_attention.kernel import flash_attention_bhsd
    import jax.numpy as jnp
    S, hd = 256, 64
    q, k, v = (jnp.asarray(rng.standard_normal((B, S, hd)), jnp.float32)
               for _ in range(3))
    out = []
    for bq in (64, 128, 256):
        for bk in (64, 128, 256):
            if S % min(bq, S) or S % min(bk, S):
                continue
            fn = jax.jit(lambda q, k, v, bq=bq, bk=bk: flash_attention_bhsd(
                q, k, v, causal=True, block_q=bq, block_k=bk))
            out.append(Candidate(
                params={"block_q": bq, "block_k": bk},
                record={"effective_block_q": min(bq, S),
                        "effective_block_k": min(bk, S)},
                run=(lambda fn=fn: jax.block_until_ready(fn(q, k, v))),
                lower=(lambda fn=fn: fn.lower(q, k, v)
                       .compile().as_text())))
    return out


def _mlstm_candidates(B: int, rng) -> list[Candidate]:
    import jax
    import jax.numpy as jnp

    from repro.kernels.mlstm_scan.kernel import mlstm_chunkwise_bh
    S, dh = 256, 32
    q, k, v = (jnp.asarray(rng.standard_normal((B, S, dh)), jnp.float32)
               for _ in range(3))
    ig = jnp.asarray(rng.standard_normal((B, S)), jnp.float32)
    fg = jnp.asarray(rng.standard_normal((B, S)), jnp.float32)
    C0 = jnp.zeros((B, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, dh), jnp.float32)
    m0 = jnp.zeros((B,), jnp.float32)
    args = (q, k, v, ig, fg, C0, n0, m0)
    out = []
    for chunk in (16, 32, 64, 128):
        if S % min(chunk, S):
            continue
        fn = jax.jit(lambda *a, chunk=chunk: mlstm_chunkwise_bh(
            *a, chunk=chunk))
        out.append(Candidate(
            params={"chunk": chunk},
            record={"effective_chunk": min(chunk, S)},
            run=(lambda fn=fn: jax.block_until_ready(fn(*args))),
            lower=(lambda fn=fn: fn.lower(*args).compile().as_text())))
    return out


# kernel -> (candidate builder, default batches, --fast batches).  The
# router sweep runs at serving decision batches (the ISSUE's 1k-16k
# range); the model kernels tune over their model-batch axis, which is
# what their ops wrappers key ``tiles.tile_for`` on.
KERNELS = {
    "router_score": (_router_candidates, (1000, 4000, 16000), (128, 256)),
    "flash_attention": (_flash_candidates, (8, 32), (2,)),
    "mlstm_scan": (_mlstm_candidates, (8, 32), (2,)),
}


def model_candidate(cand: Candidate, hw: HWPreset) -> float:
    """Roofline bound (seconds) for one lowered candidate."""
    la = loop_aware_totals(cand.lower())
    rl = Roofline(flops=la["dot_flops"], hbm_bytes=la["traffic_bytes"],
                  collective_bytes=la["collective_bytes"], hw=hw)
    return rl.t_bound


def measure_candidate(cand: Candidate, repeats: int) -> float:
    """Median wall time of ``repeats`` runs after one warmup call."""
    cand.run()                                    # compile + warm caches
    ts = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        cand.run()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def tune_kernel(kernel: str, batches, hw: HWPreset, *, repeats: int = 5,
                measure: bool = True, measure_top: int = 3,
                seed: int = 0) -> dict:
    """Sweep one kernel over ``batches``; returns {batch: entry}."""
    builder = KERNELS[kernel][0]
    out = {}
    for B in batches:
        rng = np.random.default_rng(seed + B)
        cands = builder(int(B), rng)
        for c in cands:
            c.modeled_s = model_candidate(c, hw)
        cands.sort(key=lambda c: c.modeled_s)
        if measure:
            for c in cands[:max(1, measure_top)]:
                c.measured_s = measure_candidate(c, repeats)
            winner = min(cands[:max(1, measure_top)],
                         key=lambda c: c.measured_s)
        else:
            winner = cands[0]
        out[int(B)] = {**winner.params, **winner.record,
                       "modeled_s": winner.modeled_s,
                       "measured_s": winner.measured_s}
    return out


def autotune(kernels=None, batches=None, preset: str | None = "auto", *,
             repeats: int = 5, measure: bool = True, fast: bool = False,
             seed: int = 0, log=None) -> dict:
    """Run the sweep; returns the full table dict (not yet persisted).

    ``batches`` overrides the router_score batch list only — the model
    kernels keep their own model-batch axes.  ``fast`` shrinks every
    batch list for CI smoke runs.
    """
    import jax
    hw = resolve_preset(preset)
    backend = jax.default_backend()
    table: dict = {"version": 1, backend: {}}
    for kernel in (kernels or list(KERNELS)):
        _, full, quick = KERNELS[kernel]
        bs = quick if fast else full
        if kernel == "router_score" and batches:
            bs = batches
        if log:
            log(f"[autotune] {kernel} @ {list(bs)} on {backend} "
                f"(hw={hw.name}, measure={measure})")
        entries = tune_kernel(kernel, bs, hw, repeats=repeats,
                              measure=measure, seed=seed)
        table[backend][kernel] = {str(b): e for b, e in entries.items()}
        if log:
            for b, e in entries.items():
                log(f"[autotune]   batch {b}: {e}")
    return table


def write_table(table: dict, path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(table, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def merge_table(new: dict, path: str) -> dict:
    """Overlay ``new`` onto an existing table file (other backends and
    kernels keep their entries); returns the merged dict."""
    try:
        with open(path) as f:
            old = json.load(f)
        assert isinstance(old, dict)
    except (OSError, ValueError, AssertionError):
        return new
    for backend, kernels in new.items():
        if backend == "version":
            continue
        dst = old.setdefault(backend, {})
        for kernel, entries in kernels.items():
            dst.setdefault(kernel, {}).update(entries)
    old["version"] = new.get("version", 1)
    return old


def main(argv=None):
    from repro.kernels import tiles
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--out", default=tiles.DEFAULT_PATH,
                   help="tile table path (merged with existing entries)")
    p.add_argument("--batches", type=lambda s: [int(x) for x in
                                                s.split(",")],
                   default=None,
                   help="router_score batch list, e.g. 1000,4000,16000")
    p.add_argument("--kernels", type=lambda s: s.split(","),
                   default=None, help="subset of " + ",".join(KERNELS))
    p.add_argument("--preset", default="auto",
                   help="hardware preset: auto, tpu-v5e, gpu, cpu")
    p.add_argument("--repeats", type=int, default=5)
    p.add_argument("--no-measure", action="store_true",
                   help="rank by roofline model only (deterministic)")
    p.add_argument("--fast", action="store_true",
                   help="tiny batch lists for smoke runs")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)
    for k in args.kernels or ():
        if k not in KERNELS:
            p.error(f"unknown kernel {k!r} (have {', '.join(KERNELS)})")
    table = autotune(args.kernels, args.batches, args.preset,
                     repeats=args.repeats, measure=not args.no_measure,
                     fast=args.fast, seed=args.seed, log=print)
    write_table(merge_table(table, args.out), args.out)
    print(f"[autotune] wrote {args.out}")


if __name__ == "__main__":
    main()
