"""Training driver.

Two modes:
  --arch <id>         train a REDUCED variant of an assigned architecture on
                      the synthetic corpus for N steps on the host devices
                      (CPU-scale integration of the exact production
                      train_step path: same builders, same sharding rules,
                      host mesh instead of the 16x16 pod).
  --tryage            run the full Tryage pipeline (experts + router),
                      i.e. the paper's training recipe end-to-end.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --steps 20
  PYTHONPATH=src python -m repro.launch.train --tryage --fast
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def train_arch(arch: str, steps: int, batch: int, seq: int, verbose=True):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.rngs import seeded_key
    from repro.data.batching import mlm_batch
    from repro.data.corpus import DomainCorpus
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import PerfKnobs, build_train_step
    from repro.models.common import InputShape
    from repro.models.model import init_model
    from repro.optim import adamw_init

    cfg = get_config(arch).reduced()
    shape = InputShape(name="host", seq_len=seq, global_batch=batch,
                       kind="train")
    mesh = make_host_mesh(1, 1)
    built = build_train_step(cfg, shape, mesh, PerfKnobs(donate=False),
                             lr=1e-3)

    key = seeded_key(0)
    params, _ = init_model(key, cfg)
    opt = adamw_init(params)
    opt = {"step": opt.step, "mu": opt.mu, "nu": opt.nu}
    corpus = DomainCorpus(vocab_size=cfg.vocab_size)
    rng = np.random.default_rng(0)
    uniform = {d: 1.0 / 8 for d in corpus.tables}

    losses = []
    with mesh:
        for i in range(steps):
            toks, _lab = corpus.sample_mixture(uniform, batch, seq, rng)
            toks = np.clip(toks, 0, cfg.vocab_size - 1)
            if cfg.is_encoder or cfg.family in ("vlm", "audio"):
                mb = mlm_batch(toks, rng, 0.15, cfg.vocab_size)
                batch_in = {
                    "embeds": jnp.asarray(
                        rng.standard_normal((batch, seq, cfg.d_model)),
                        jnp.float32),
                    "targets": jnp.asarray(mb["targets"]),
                    "mask": jnp.asarray(mb["mask"])}
                if cfg.family not in ("vlm", "audio"):
                    batch_in["tokens"] = jnp.asarray(mb["tokens"])
            else:
                batch_in = {"tokens": jnp.asarray(toks),
                            "mask": jnp.ones((batch, seq), jnp.int32)}
            params, opt, loss = built.fn(params, opt, batch_in)
            losses.append(float(loss))
            if verbose and (i % 5 == 0 or i == steps - 1):
                print(f"  {arch} step {i}: loss {float(loss):.4f}", flush=True)
    assert np.isfinite(losses).all(), "NaN loss"
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--tryage", action="store_true")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    if args.tryage:
        from repro.core.experiment import ExperimentConfig, run_experiment
        xc = ExperimentConfig()
        if args.fast:
            xc = ExperimentConfig(expert_steps=60, n_train_prompts=512,
                                  n_val_prompts=128, n_test_per_domain=24,
                                  router_epochs=3)
        run_experiment(xc)
        return
    assert args.arch, "--arch or --tryage required"
    t0 = time.time()
    losses = train_arch(args.arch, args.steps, args.batch, args.seq)
    print(f"{args.arch}: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({time.time()-t0:.0f}s)")


if __name__ == "__main__":
    main()
