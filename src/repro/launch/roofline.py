"""Three-term roofline model from the compiled dry-run artifact.

    compute    = HLO_FLOPs / peak_FLOPs_per_chip
    memory     = HLO_bytes / HBM_bw_per_chip
    collective = collective_bytes / (links * link_bw)

All inputs are per-chip (cost_analysis and the parsed HLO are post-SPMD).
Hardware constants come from a preset table (``PRESETS``) selected
explicitly or by backend detection (``detect_preset``); the default stays
TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI — so
existing callers are unchanged.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HWPreset:
    """Per-chip hardware ceilings for one accelerator target."""

    name: str
    peak_flops: float       # FLOP/s (dense matmul, bf16 or vendor peak)
    hbm_bw: float           # bytes/s main-memory bandwidth
    ici_bw: float           # bytes/s per interconnect link
    ici_links: int = 1      # links counted as serializing collectives


PRESETS = {
    # TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~4 usable ICI links/chip
    # but conservatively count 1 link serializing all collective traffic
    "tpu-v5e": HWPreset("tpu-v5e", 197e12, 819e9, 50e9, 1),
    # A100-class GPU: 312 TFLOP/s bf16, 2.04 TB/s HBM2e, 600 GB/s NVLink
    "gpu": HWPreset("gpu", 312e12, 2.04e12, 600e9, 1),
    # server-class CPU socket: ~1 TFLOP/s f32, ~100 GB/s DDR, ~10 GB/s
    # inter-socket — only useful for relative tile ranking, not absolute
    # time prediction
    "cpu": HWPreset("cpu", 1e12, 100e9, 10e9, 1),
}

# module-level constants kept for back-compat (dryrun.py and older tests
# read them); they mirror the default preset
_DEFAULT = PRESETS["tpu-v5e"]
PEAK_FLOPS = _DEFAULT.peak_flops
HBM_BW = _DEFAULT.hbm_bw
ICI_BW = _DEFAULT.ici_bw
ICI_LINKS = _DEFAULT.ici_links


def detect_preset() -> HWPreset:
    """The preset matching the live JAX backend (``tpu`` -> tpu-v5e,
    ``gpu``/``cuda``/``rocm`` -> gpu, anything else -> cpu).  Lazy
    import: the module stays importable without a working backend."""
    try:
        import jax
        backend = jax.default_backend()
    except Exception:
        return PRESETS["cpu"]
    if backend == "tpu":
        return PRESETS["tpu-v5e"]
    if backend in ("gpu", "cuda", "rocm"):
        return PRESETS["gpu"]
    return PRESETS["cpu"]


def resolve_preset(name: str | None) -> HWPreset:
    """Preset by name; ``None`` or ``"auto"`` detects from the backend."""
    if name is None or name == "auto":
        return detect_preset()
    return PRESETS[name]


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    hw: HWPreset = _DEFAULT

    @property
    def t_compute(self):
        return self.flops / self.hw.peak_flops

    @property
    def t_memory(self):
        return self.hbm_bytes / self.hw.hbm_bw

    @property
    def t_collective(self):
        return self.collective_bytes / (self.hw.ici_bw * self.hw.ici_links)

    @property
    def dominant(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self):
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self):
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "hw": self.hw.name,
        }


def model_flops(cfg, shape, params_active: float) -> float:
    """6·N·D reference FLOPs (N = active params, D = tokens) — global."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * params_active * tokens
    # decode: one token per sequence
    return 2.0 * params_active * shape.global_batch


def active_params(cfg, total_params: float) -> float:
    """Active (per-token) parameter count for MoE archs."""
    if cfg.moe is None:
        return total_params
    m = cfg.moe
    dff = m.d_ff_expert or cfg.d_ff
    per_expert = 3 * cfg.d_model * dff
    n_layers_moe = sum(cfg.moe_pattern) * (cfg.num_layers // len(cfg.moe_pattern))
    inactive = per_expert * (m.num_experts - m.top_k) * n_layers_moe
    return total_params - inactive
