"""Three-term roofline model from the compiled dry-run artifact.

    compute    = HLO_FLOPs / peak_FLOPs_per_chip
    memory     = HLO_bytes / HBM_bw_per_chip
    collective = collective_bytes / (links * link_bw)

All inputs are per-chip (cost_analysis and the parsed HLO are post-SPMD).
Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (v5e: ~4 usable links/chip,
ICI_LINKS = 1                # conservatively count 1 link serializing all
                             # collective traffic (worst case)


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float

    @property
    def t_compute(self):
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self):
        return self.collective_bytes / (ICI_BW * ICI_LINKS)

    @property
    def dominant(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self):
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self):
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
        }


def model_flops(cfg, shape, params_active: float) -> float:
    """6·N·D reference FLOPs (N = active params, D = tokens) — global."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * params_active * tokens
    # decode: one token per sequence
    return 2.0 * params_active * shape.global_batch


def active_params(cfg, total_params: float) -> float:
    """Active (per-token) parameter count for MoE archs."""
    if cfg.moe is None:
        return total_params
    m = cfg.moe
    dff = m.d_ff_expert or cfg.d_ff
    per_expert = 3 * cfg.d_model * dff
    n_layers_moe = sum(cfg.moe_pattern) * (cfg.num_layers // len(cfg.moe_pattern))
    inactive = per_expert * (m.num_experts - m.top_k) * n_layers_moe
    return total_params - inactive
