"""ShapeDtypeStruct input stand-ins for every (arch x input-shape) pair.

``input_specs`` returns abstract inputs only — no device allocation — so
full-size 314B-parameter configs can be lowered on a CPU host.  For VLM /
audio architectures the modality frontend is stubbed per the assignment:
train/prefill consume precomputed patch/frame embeddings of the right
shape; decode consumes text token ids.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import InputShape, ModelConfig

SDS = jax.ShapeDtypeStruct


def applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether this (arch, shape) pair runs, and the skip reason if not."""
    if shape.kind == "decode" and cfg.is_encoder:
        return False, "encoder-only architecture has no decode step"
    if shape.name == "long_500k":
        sub_quadratic = cfg.family in ("ssm", "hybrid") or (
            cfg.attn.sliding_window > 0)
        if not sub_quadratic:
            return False, ("pure full-attention architecture; 500k decode "
                           "requires sub-quadratic attention")
    return True, ""


def batch_specs(cfg: ModelConfig, shape: InputShape):
    """Abstract train/prefill batch for this arch."""
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.jnp_dtype
    if cfg.family in ("vlm", "audio"):
        batch = {"embeds": SDS((B, S, cfg.d_model), dt),
                 "targets": SDS((B, S), jnp.int32),
                 "mask": SDS((B, S), jnp.int32)}
    else:
        batch = {"tokens": SDS((B, S), jnp.int32),
                 "mask": SDS((B, S), jnp.int32)}
    return batch


def batch_logical(cfg: ModelConfig, shape: InputShape):
    if cfg.family in ("vlm", "audio"):
        return {"embeds": ("batch", "seq", "act_embed"),
                "targets": ("batch", "seq"), "mask": ("batch", "seq")}
    return {"tokens": ("batch", "seq"), "mask": ("batch", "seq")}


def decode_token_specs(cfg: ModelConfig, shape: InputShape):
    return {"tokens": SDS((shape.global_batch, 1), jnp.int32)}


def decode_token_logical(cfg: ModelConfig):
    return {"tokens": ("batch", "seq")}
