"""Step builders: produce (jitted fn, abstract args, shardings) for every
(arch x input-shape x mesh) combination.

All three step kinds are built from abstract shapes only; ``.lower()`` +
``.compile()`` on them is the multi-pod dry-run.  The same builders drive
the real CPU-scale training/serving paths (with concrete arrays).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import specs as specs_lib
from repro.models import model as model_lib
from repro.models.common import InputShape, ModelConfig
from repro.optim import adamw_update
from repro.sharding import (DEFAULT_RULES, MULTIPOD_RULES, LogicalRules,
                            activation_sharding, tree_logical_to_spec)

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class PerfKnobs:
    """Tunables iterated during the §Perf hillclimb."""
    microbatch: int = 1
    moment_dtype: str = "float32"
    remat: bool = True
    attn_impl: str = "xla"
    unit_group: int = 1      # sqrt-depth remat: boundaries every g units
    # extra logical-rule overrides, e.g. {"expert": ("data", "model")}
    rule_overrides: dict | None = None
    donate: bool = True


def rules_for(mesh, knobs: PerfKnobs | None = None) -> LogicalRules:
    base = MULTIPOD_RULES if "pod" in mesh.axis_names else DEFAULT_RULES
    if knobs and knobs.rule_overrides:
        return LogicalRules(rules={**base.rules, **knobs.rule_overrides})
    return base


def _opt_logical(params_logical):
    return {"step": (), "mu": params_logical, "nu": params_logical}


def _spec_tree(mesh, logical, shapes, rules):
    return tree_logical_to_spec(mesh, logical, shapes, rules)


@dataclasses.dataclass
class BuiltStep:
    fn: Any                  # jitted
    args: tuple              # abstract ShapeDtypeStructs
    in_specs: tuple
    arg_names: tuple


def _opt_state_abstract(params_abs, moment_dtype):
    mu = jax.tree.map(lambda p: SDS(p.shape, jnp.dtype(moment_dtype)), params_abs)
    nu = jax.tree.map(lambda p: SDS(p.shape, jnp.dtype(moment_dtype)), params_abs)
    return {"step": SDS((), jnp.int32), "mu": mu, "nu": nu}


def build_train_step(cfg: ModelConfig, shape: InputShape, mesh,
                     knobs: PerfKnobs = PerfKnobs(), lr=5e-5):
    rules = rules_for(mesh, knobs)
    params_abs, logical = model_lib.init_model_logical(cfg)
    batch_abs = specs_lib.batch_specs(cfg, shape)
    batch_log = specs_lib.batch_logical(cfg, shape)

    n_micro = knobs.microbatch
    moment_dt = jnp.dtype(knobs.moment_dtype)

    def loss_fn(p, b):
        return model_lib.lm_loss(p, cfg, b, remat=knobs.remat,
                                 attn_impl=knobs.attn_impl,
                                 unit_group=knobs.unit_group)

    def train_step(params, opt, batch):
      with activation_sharding(mesh, rules):
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(b):
                return jax.tree.map(
                    lambda a: a.reshape((n_micro, a.shape[0] // n_micro)
                                        + a.shape[1:]), b)
            mb = micro(batch)

            def acc_body(carry, b):
                acc, loss_acc = carry
                (loss, _m), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, b)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(a.dtype), acc, g)
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                acc_body, (zeros, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
        new_params, new_opt = adamw_update(params, grads, _OptShim(opt),
                                           lr=lr, weight_decay=1e-5)
        return new_params, _opt_as_dict(new_opt), loss

    # shardings ------------------------------------------------------
    p_specs = _spec_tree(mesh, logical, params_abs, rules)
    opt_abs = _opt_state_abstract(params_abs, moment_dt)
    opt_specs = {"step": P(), "mu": p_specs, "nu": p_specs}
    b_specs = _spec_tree(mesh, batch_log, batch_abs, rules)
    in_specs = (p_specs, opt_specs, b_specs)
    out_specs = (p_specs, opt_specs, P())
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), in_specs,
                             is_leaf=lambda x: isinstance(x, P))
    out_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), out_specs,
                                 is_leaf=lambda x: isinstance(x, P))
    fn = jax.jit(train_step, in_shardings=shardings,
                 out_shardings=out_shardings,
                 donate_argnums=(0, 1) if knobs.donate else ())
    return BuiltStep(fn=fn, args=(params_abs, opt_abs, batch_abs),
                     in_specs=in_specs, arg_names=("params", "opt", "batch"))


class _OptShim:
    """Adapt dict opt-state to the OptState attribute interface."""

    def __init__(self, d):
        self.step, self.mu, self.nu = d["step"], d["mu"], d["nu"]


def _opt_as_dict(o):
    return {"step": o.step, "mu": o.mu, "nu": o.nu}


def build_prefill_step(cfg: ModelConfig, shape: InputShape, mesh,
                       knobs: PerfKnobs = PerfKnobs()):
    rules = rules_for(mesh, knobs)
    params_abs, logical = model_lib.init_model_logical(cfg)
    batch_abs = specs_lib.batch_specs(cfg, shape)
    batch_abs.pop("targets", None), batch_abs.pop("mask", None)
    batch_log = {k: v for k, v in specs_lib.batch_logical(cfg, shape).items()
                 if k in batch_abs}

    def prefill_step(params, batch):
        with activation_sharding(mesh, rules):
            logits, state = model_lib.prefill(params, cfg, batch,
                                              attn_impl=knobs.attn_impl)
            return logits[:, -1].astype(jnp.float32), state

    p_specs = _spec_tree(mesh, logical, params_abs, rules)
    b_specs = _spec_tree(mesh, batch_log, batch_abs, rules)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s),
                             (p_specs, b_specs),
                             is_leaf=lambda x: isinstance(x, P))
    fn = jax.jit(prefill_step, in_shardings=shardings)
    return BuiltStep(fn=fn, args=(params_abs, batch_abs),
                     in_specs=(p_specs, b_specs), arg_names=("params", "batch"))


def build_decode_step(cfg: ModelConfig, shape: InputShape, mesh,
                      knobs: PerfKnobs = PerfKnobs()):
    rules = rules_for(mesh, knobs)
    params_abs, logical = model_lib.init_model_logical(cfg)
    B, S = shape.global_batch, shape.seq_len
    state_abs = jax.eval_shape(
        lambda: model_lib.init_decode_state(cfg, B, S))
    state_log = model_lib.decode_state_logical(cfg)
    tok_abs = specs_lib.decode_token_specs(cfg, shape)
    tok_log = specs_lib.decode_token_logical(cfg)

    def serve_step(params, state, tok, index):
        with activation_sharding(mesh, rules):
            logits, new_state = model_lib.decode_step(
                params, cfg, tok, state, index, attn_impl=knobs.attn_impl)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            return next_tok, new_state

    p_specs = _spec_tree(mesh, logical, params_abs, rules)
    s_specs = _spec_tree(mesh, state_log, state_abs, rules)
    t_specs = _spec_tree(mesh, tok_log, tok_abs, rules)
    in_specs = (p_specs, s_specs, t_specs, P())
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), in_specs,
                             is_leaf=lambda x: isinstance(x, P))
    out_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        (t_specs["tokens"], s_specs), is_leaf=lambda x: isinstance(x, P))
    fn = jax.jit(serve_step, in_shardings=shardings,
                 out_shardings=out_shardings,
                 donate_argnums=(1,) if knobs.donate else ())
    args = (params_abs, state_abs, tok_abs, SDS((), jnp.int32))
    return BuiltStep(fn=fn, args=args, in_specs=in_specs,
                     arg_names=("params", "state", "tokens", "index"))


def build_step(cfg: ModelConfig, shape: InputShape, mesh,
               knobs: PerfKnobs = PerfKnobs()):
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, knobs)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, knobs)
    if shape.kind == "decode":
        return build_decode_step(cfg, shape, mesh, knobs)
    raise ValueError(shape.kind)
