"""Serving driver: bring up a TryageEngine over the trained library and
drive the streaming API with a Poisson arrival simulator.

  PYTHONPATH=src python -m repro.launch.serve --requests 256 [--fast] \
      [--use-kernel] [--no-buckets] [--fifo] [--arrival-rate 200] \
      [--max-wait-s 0.05] [--priority-mix 0.9,0.08,0.02] \
      [--cascade 0.6] [--cascade-depth 2] [--fused-cascade] \
      [--speculate] [--tile-table PATH] \
      [--adapt-every 16 --adapt-lr 0.05 --replay-cap 1024] \
      [--drift-after 128 --drift-domains github,dm_math] \
      [--sessions 4 --admission-cap 256] [--fallback-depth 2] \
      [--fail-expert small --fail-after 64] \
      [--mesh 2,4 --replicate-hot 1] \
      [--cache-tiers exact,persistent,semantic --cache-dir cache/ \
       --cache-semantic 0.5] \
      [--metrics-port 9109] [--metrics-out metrics.prom]

By default requests flow through ``TryageEngine.serve`` — the
continuous-batching scheduler that coalesces same-expert requests
across admission batches into full power-of-two buckets and flushes a
lane early when its oldest request has waited ``--max-wait-s``.
``--fifo`` switches back to the per-batch FIFO drain (``run()``) for
comparison.  ``--arrival-rate`` is the Poisson arrival intensity in
requests/second (0 = all requests arrive at once); ``--priority-mix``
gives the fraction of requests at priority 0, 1, 2, ...

--use-kernel routes every decision through the fused Pallas head
(compiled on TPU/GPU, interpret on CPU); --no-buckets disables the
power-of-two padding of per-expert micro-batches.  Loads artifacts from
experiments/tryage if present, otherwise trains a reduced library first.

--cascade T enables confidence-aware cascade routing: every request
carries ``min_confidence = T``, and a request whose chosen expert the
router is not confident about (calibrated confidence < T) escalates to
the next-larger expert via the scheduler's escalation lanes, up to
--cascade-depth steps.  If the loaded router checkpoint predates the
uncertainty head, one is calibrated on the fly against the cached
held-out Q-table (a few seconds, head-only training).
--fused-cascade (with --use-kernel) resolves score, confidence and the
depth-1 escalation in one Pallas launch; --speculate lanes every
request on its router choice immediately and resolves the escalation
verdict after the tick's flushes launch (speculation telemetry lands
in the summary JSON and the Prometheus metrics).  --tile-table points
the kernels at an autotuned tile table (see launch/autotune.py).

Online adaptation + drift: --adapt-every N turns on feedback-driven
router refresh (one incremental update per N observed losses, replayed
from a --replay-cap bounded buffer at --adapt-lr); the summary JSON
reports updates applied, the final router version, and the pre/post
update prediction error.  --drift-after R simulates a mid-stream
domain shift: the first R requests are drawn from the uniform domain
mix, everything after from a mix concentrated on --drift-domains —
watch the adaptation telemetry track the shift (or freeze the router
with --adapt-every 0 and watch it go stale).

Front end + health + metrics: --sessions N multiplexes the request
stream over N concurrent client sessions through the bounded admission
queue (--admission-cap; overflow load-sheds the lowest-priority request
in play).  --fallback-depth D attaches an ExpertHealth tracker and lets
the Route stage walk up to D fallback re-selections around unhealthy or
saturated experts; --fail-expert NAME arms a persistent failure
injection on that expert's lanes once --fail-after requests have been
admitted — with fallback on, traffic re-routes around it; with
--fallback-depth 0 its requests fail terminally (Result.failed).
--metrics-port P serves Prometheus text metrics at
http://127.0.0.1:P/metrics for the duration of the run; --metrics-out
FILE writes a final scrape to FILE.  See docs/OPERATIONS.md.

Cache tiers: --cache-tiers picks which decision-cache tiers are live
(comma list; ``exact`` is the in-process LRU and is always on,
``persistent`` adds the restart-safe disk KV under --cache-dir,
``semantic`` adds the embedding nearest-neighbour tier with distance
bound --cache-semantic EPS).  ``--cache-tiers exact`` (the default) is
bit-for-bit the pre-tier engine.  See docs/ARCHITECTURE.md "Decision
cache tiers".

Mesh serving: --mesh DATA,MODEL builds a (data, model) device mesh
(``launch.mesh.make_host_mesh``) — the routing stage shards admission
batches over the data axis and each expert is placed on a model-axis
slice (``serving.placement``; greedy size-balanced, --replicate-hot K
replicates the K hottest experts everywhere), so lane flushes overlap
in per-device streams.  The summary JSON gains a "mesh" block with the
placement and per-stream busy times.  On CPU, simulate devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def poisson_arrivals(reqs, rate: float, rng,
                     now_fn=time.monotonic, sleep_fn=time.sleep):
    """Yield ``reqs`` with exponential inter-arrival gaps at ``rate``
    req/s, emitting ``None`` idle ticks while waiting so the engine's
    scheduler can fire deadline flushes between arrivals.  ``rate <= 0``
    yields everything back-to-back (a closed-loop benchmark)."""
    if rate <= 0:
        yield from reqs
        return
    t_next = now_fn()
    for r in reqs:
        t_next += rng.exponential(1.0 / rate)
        while now_fn() < t_next:
            yield None
            remaining = t_next - now_fn()
            if remaining > 0:
                sleep_fn(min(remaining, 1e-3))
        r.arrival = now_fn()
        yield r


def parse_priority_mix(spec: str) -> list[float]:
    """'0.9,0.08,0.02' -> normalized fractions for priorities 0,1,2."""
    fracs = [float(x) for x in spec.split(",") if x.strip()]
    total = sum(fracs)
    if not fracs or total <= 0:
        return [1.0]
    return [f / total for f in fracs]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--use-kernel", action="store_true",
                    help="fused Pallas router decision path")
    ap.add_argument("--no-buckets", action="store_true",
                    help="disable power-of-two expert micro-batch padding")
    ap.add_argument("--fifo", action="store_true",
                    help="FIFO drain instead of the continuous-batching "
                         "scheduler")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson arrival intensity, req/s (0 = all at once)")
    ap.add_argument("--max-wait-s", type=float, default=0.05,
                    help="lane deadline before a partial bucket flushes")
    ap.add_argument("--lane-target", type=int, default=None,
                    help="lane occupancy that flushes a full bucket "
                         "(default: bucket_size(max_batch))")
    ap.add_argument("--priority-mix", type=str, default="0.9,0.08,0.02",
                    help="comma fractions of requests at priority 0,1,2,...")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the router-decision cache")
    ap.add_argument("--cache-tiers", type=str, default="exact",
                    help="comma list of decision-cache tiers: exact "
                         "(in-process LRU, always on), persistent "
                         "(restart-safe disk KV, needs --cache-dir), "
                         "semantic (embedding NN tier, needs "
                         "--cache-semantic)")
    ap.add_argument("--cache-dir", type=str, default="",
                    help="directory of the persistent cache tier's "
                         "segment log (shared across engine replicas)")
    ap.add_argument("--cache-semantic", type=float, default=0.0,
                    metavar="EPS",
                    help="distance bound of the semantic cache tier "
                         "(0 = off; calibrate with benchmarks/run.py "
                         "cache)")
    ap.add_argument("--cascade", type=float, default=0.0, metavar="T",
                    help="confidence threshold for cascade escalation "
                         "(0 = single-shot routing, the default)")
    ap.add_argument("--cascade-depth", type=int, default=2,
                    help="max escalation steps per request")
    ap.add_argument("--fused-cascade", action="store_true",
                    help="with --use-kernel and --cascade, resolve "
                         "score + confidence + depth-1 escalation in "
                         "one fused Pallas launch (choices identical "
                         "to the staged path)")
    ap.add_argument("--speculate", action="store_true",
                    help="speculative escalation: lane every request "
                         "on its router choice immediately and resolve "
                         "the cascade verdict after the tick's flushes "
                         "launch (needs --cascade; incompatible with "
                         "--fallback-depth)")
    ap.add_argument("--tile-table", type=str, default="", metavar="PATH",
                    help="autotuned kernel tile table (default: "
                         "experiments/tryage/tile_table.json or "
                         "$REPRO_TILE_TABLE; regenerate with python -m "
                         "repro.launch.autotune)")
    ap.add_argument("--adapt-every", type=int, default=0, metavar="N",
                    help="router update every N observed losses "
                         "(0 = frozen router, the default)")
    ap.add_argument("--adapt-lr", type=float, default=0.05,
                    help="learning rate of the incremental router update")
    ap.add_argument("--replay-cap", type=int, default=1024,
                    help="bounded feedback replay-buffer capacity")
    ap.add_argument("--drift-after", type=int, default=0, metavar="R",
                    help="switch the domain mix after R requests "
                         "(0 = no drift, the default)")
    ap.add_argument("--drift-domains", type=str, default="github,dm_math",
                    help="comma list of domains the post-shift mix "
                         "concentrates on")
    ap.add_argument("--sessions", type=int, default=0, metavar="N",
                    help="multiplex the stream over N concurrent client "
                         "sessions through the front end's bounded "
                         "admission queue (0 = direct iterator)")
    ap.add_argument("--admission-cap", type=int, default=256,
                    help="front-end admission-queue bound; overflow "
                         "load-sheds the lowest-priority request")
    ap.add_argument("--fallback-depth", type=int, default=0, metavar="D",
                    help="attach a health tracker and walk up to D "
                         "fallback re-selections around unhealthy or "
                         "saturated experts (0 = health-unaware, the "
                         "default)")
    ap.add_argument("--fail-expert", type=str, default="",
                    help="arm a persistent failure injection on this "
                         "expert's lanes (by name) once --fail-after "
                         "requests have been admitted")
    ap.add_argument("--fail-after", type=int, default=0,
                    help="admitted-request count that triggers "
                         "--fail-expert")
    ap.add_argument("--mesh", type=str, default="", metavar="DATA,MODEL",
                    help="serve on a (data, model) device mesh: the "
                         "routing stage shards admission batches over "
                         "DATA devices and experts are placed on MODEL "
                         "slices (e.g. --mesh 2,4 on 8 devices; needs "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N on CPU)")
    ap.add_argument("--replicate-hot", type=int, default=0, metavar="K",
                    help="with --mesh, replicate the K hottest experts "
                         "onto every model slice (flushes pick the "
                         "least-busy replica stream)")
    ap.add_argument("--metrics-port", type=int, default=0, metavar="P",
                    help="serve Prometheus text metrics on "
                         "http://127.0.0.1:P/metrics during the run "
                         "(0 = off)")
    ap.add_argument("--metrics-out", type=str, default="",
                    help="write a final metrics scrape to this file")
    ap.add_argument("--sanitize", action="store_true",
                    help="enable the checkify sanitizer (NaN/inf + OOB "
                         "checks on the routing path; same switch as "
                         "REPRO_SANITIZE=1)")
    args = ap.parse_args()
    if args.adapt_every > 0 and args.replay_cap <= 0:
        ap.error("--adapt-every needs a replay buffer (--replay-cap >= 1)")
    tiers = {t.strip() for t in args.cache_tiers.split(",") if t.strip()}
    unknown_tiers = tiers - {"exact", "persistent", "semantic"}
    if unknown_tiers:
        ap.error(f"--cache-tiers: unknown tier(s) {sorted(unknown_tiers)} "
                 f"(choose from exact, persistent, semantic)")
    if "persistent" in tiers and not args.cache_dir:
        ap.error("--cache-tiers persistent needs --cache-dir")
    if "semantic" in tiers and args.cache_semantic <= 0:
        ap.error("--cache-tiers semantic needs --cache-semantic EPS > 0")
    if args.no_cache and tiers - {"exact"}:
        ap.error("--no-cache conflicts with --cache-tiers "
                 "persistent/semantic")

    if args.fused_cascade and not args.use_kernel:
        ap.error("--fused-cascade needs --use-kernel")
    if args.fused_cascade and args.cascade <= 0:
        ap.error("--fused-cascade needs --cascade T > 0")
    if args.speculate and args.cascade <= 0:
        ap.error("--speculate needs --cascade T > 0")
    if args.speculate and (args.fallback_depth > 0 or args.fail_expert):
        ap.error("--speculate is incompatible with the health tracker "
                 "(--fallback-depth/--fail-expert): deferred verdicts "
                 "cannot reorder around the health consult")
    if args.speculate and args.fifo:
        ap.error("--speculate needs the scheduler (drop --fifo)")

    if args.tile_table:
        from repro.kernels import tiles
        tiles.set_table_path(args.tile_table)

    if args.sanitize:
        from repro.kernels import sanitize
        sanitize.set_sanitize(True)

    from repro.core import experiment as ex
    from repro.core.objective import recency_constraint, size_constraint
    from repro.data.batching import mlm_batch
    from repro.serving import (ExpertHealth, Request, ServingFrontend,
                               Session, TryageEngine)
    from repro.serving.metrics import render, start_metrics_server

    try:
        art = ex.load_artifacts()
    except FileNotFoundError:
        print("no artifacts; running reduced experiment first", flush=True)
        xc = ex.ExperimentConfig(expert_steps=60, n_train_prompts=512,
                                 n_val_prompts=128, n_test_per_domain=24,
                                 router_epochs=3)
        ex.run_experiment(xc, verbose=True)
        art = ex.load_artifacts()

    lib, rp, rc, corpus = (art["library"], art["router_params"], art["rc"],
                           art["corpus"])
    if args.cascade > 0 and "unc" not in rp:
        from repro.core.training import calibrate_uncertainty
        print("calibrating uncertainty head on held-out Q-table", flush=True)
        rp = calibrate_uncertainty(rp, rc, art["test_tokens"],
                                   art["q_test"]["loss"])
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh
        try:
            mdata, mmodel = (int(x) for x in args.mesh.split(","))
        except ValueError:
            ap.error("--mesh expects two integers 'data,model'")
        mesh = make_host_mesh(mdata, mmodel)
    elif args.replicate_hot:
        ap.error("--replicate-hot needs --mesh")
    health = (ExpertHealth(len(lib))
              if args.fallback_depth > 0 or args.fail_expert else None)
    eng = TryageEngine(lib, rp, rc,
                       [size_constraint(lib), recency_constraint(lib)],
                       max_batch=args.max_batch,
                       use_kernel=args.use_kernel,
                       buckets=not args.no_buckets,
                       lane_target=args.lane_target,
                       max_wait_s=args.max_wait_s,
                       decision_cache=not args.no_cache,
                       cache_dir=(args.cache_dir
                                  if "persistent" in tiers else None),
                       cache_semantic_eps=(args.cache_semantic
                                           if "semantic" in tiers else 0.0),
                       cascade_max_depth=args.cascade_depth,
                       fused_cascade=args.fused_cascade,
                       speculate=args.speculate,
                       adapt_every=args.adapt_every,
                       adapt_lr=args.adapt_lr,
                       replay_cap=args.replay_cap,
                       health=health,
                       fallback_max_depth=args.fallback_depth,
                       mesh=mesh,
                       replicate_hot=args.replicate_hot)
    if mesh is not None:
        # pre-compile every (expert, replica device, bucket) variant so
        # dispatch never eats a compile inside measured traffic
        eng.warm_mesh(args.seq)

    rng = np.random.default_rng(0)
    uniform = {d: 1.0 / 8 for d in corpus.tables}
    # drift simulator: requests [0, drift_after) sample the uniform mix,
    # the rest a mix concentrated on --drift-domains — a mid-stream
    # domain shift the adaptation loop should track
    n_pre = (min(args.drift_after, args.requests) if args.drift_after > 0
             else args.requests)
    if n_pre < args.requests:
        shift_doms = [d.strip() for d in args.drift_domains.split(",")
                      if d.strip()]
        unknown = set(shift_doms) - set(corpus.tables)
        if not shift_doms or unknown:
            raise SystemExit(f"--drift-domains must name corpus domains "
                             f"(unknown: {sorted(unknown)}; "
                             f"have: {sorted(corpus.tables)})")
        shifted = {d: 1.0 / len(shift_doms) for d in shift_doms}
        t_pre, _ = corpus.sample_mixture(uniform, n_pre, args.seq, rng)
        t_post, _ = corpus.sample_mixture(shifted, args.requests - n_pre,
                                          args.seq, rng)
        toks = np.concatenate([t_pre, t_post])
    else:
        toks, _ = corpus.sample_mixture(uniform, args.requests, args.seq,
                                        rng)
    mb = mlm_batch(toks, rng, 0.15, corpus.vocab_size)
    flag_mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]
    mix = parse_priority_mix(args.priority_mix)
    priorities = rng.choice(len(mix), size=args.requests, p=mix)
    reqs = [Request(uid=i, tokens=mb["tokens"][i], targets=mb["targets"][i],
                    mask=mb["mask"][i], lambdas=flag_mix[i % len(flag_mix)],
                    priority=int(priorities[i]),
                    min_confidence=args.cascade)
            for i in range(args.requests)]

    names = [e.name for e in lib]
    fail_idx = None
    if args.fail_expert:
        if args.fail_expert not in names:
            raise SystemExit(f"--fail-expert must be one of {names}")
        if args.fifo:
            ap.error("--fail-expert needs the scheduler (drop --fifo)")
        fail_idx = names.index(args.fail_expert)
    if args.sessions > 0 and args.fifo:
        ap.error("--sessions needs the streaming engine (drop --fifo)")

    # arm the failure injection mid-stream: once --fail-after requests
    # have been admitted, every flush of the target expert's lanes fails
    # until the end of the run
    trigger = {"n": 0, "armed": False}

    def with_failure_trigger(stream):
        for item in stream:
            yield item
            if item is not None:
                trigger["n"] += 1
                if (fail_idx is not None and not trigger["armed"]
                        and trigger["n"] >= args.fail_after):
                    trigger["armed"] = True
                    eng.scheduler.inject_failures(fail_idx)

    srv = None
    if args.metrics_port:
        srv = start_metrics_server(
            args.metrics_port,
            lambda: render(eng.stats, eng.health, names))
        print(f"metrics: http://127.0.0.1:{srv.port}/metrics", flush=True)

    t0 = time.monotonic()
    if args.fifo:
        for r in reqs:
            eng.submit(r)
        results = eng.run()
    elif args.sessions > 0:
        chunks = [reqs[i::args.sessions] for i in range(args.sessions)]
        sess = [Session(f"s{i}", with_failure_trigger(poisson_arrivals(
                    c, args.arrival_rate / args.sessions, rng)))
                for i, c in enumerate(chunks)]
        fe = ServingFrontend(eng, sess, capacity=args.admission_cap)
        results = list(fe.serve())
    else:
        arrivals = with_failure_trigger(
            poisson_arrivals(reqs, args.arrival_rate, rng))
        results = list(eng.serve(arrivals))
    dt = time.monotonic() - t0
    if srv is not None:
        srv.stop()
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(render(eng.stats, eng.health, names))
        print(f"metrics written to {args.metrics_out}", flush=True)
    if hasattr(eng.cache, "close"):       # persist the T2 segment log
        eng.cache.close()
    accs = [r.accuracy for r in results if r.accuracy is not None]
    losses = [r.loss for r in results if r.loss is not None]
    print(json.dumps({
        "requests": len(results),
        "router_path": "fused-kernel" if args.use_kernel else "host",
        "discipline": "fifo-drain" if args.fifo else "continuous-batching",
        "cascade_threshold": args.cascade,
        "fused_cascade": args.fused_cascade,
        "speculate": args.speculate,
        "adapt_every": args.adapt_every,
        "sanitize": args.sanitize,
        "drift_after": args.drift_after,
        "arrival_rate": args.arrival_rate,
        "sessions": args.sessions,
        "fallback_depth": args.fallback_depth,
        "fail_expert": args.fail_expert or None,
        "cache_tiers": sorted(tiers) if not args.no_cache else [],
        "mesh": eng.mesh_summary(),
        "wall_s": round(dt, 2),
        "req_per_s": round(len(results) / dt, 1),
        "mean_mlm_accuracy": round(float(np.mean(accs)), 4),
        "mean_mlm_loss": round(float(np.mean(losses)), 4),
        "engine": eng.stats.summary(),
    }, indent=1))


if __name__ == "__main__":
    main()
