"""Serving driver: bring up a TryageEngine over the trained library and
push batched requests through it (the paper's kind of end-to-end driver).

  PYTHONPATH=src python -m repro.launch.serve --requests 256 [--fast] \
      [--use-kernel] [--no-buckets]

--use-kernel routes every decision through the fused Pallas head
(compiled on TPU/GPU, interpret on CPU); --no-buckets disables the
power-of-two padding of per-expert micro-batches.  Loads artifacts from
experiments/tryage if present, otherwise trains a reduced library first.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--use-kernel", action="store_true",
                    help="fused Pallas router decision path")
    ap.add_argument("--no-buckets", action="store_true",
                    help="disable power-of-two expert micro-batch padding")
    args = ap.parse_args()

    from repro.core import experiment as ex
    from repro.core.objective import recency_constraint, size_constraint
    from repro.data.batching import mlm_batch
    from repro.serving import Request, TryageEngine

    try:
        art = ex.load_artifacts()
    except FileNotFoundError:
        print("no artifacts; running reduced experiment first", flush=True)
        xc = ex.ExperimentConfig(expert_steps=60, n_train_prompts=512,
                                 n_val_prompts=128, n_test_per_domain=24,
                                 router_epochs=3)
        ex.run_experiment(xc, verbose=True)
        art = ex.load_artifacts()

    lib, rp, rc, corpus = (art["library"], art["router_params"], art["rc"],
                           art["corpus"])
    eng = TryageEngine(lib, rp, rc,
                       [size_constraint(lib), recency_constraint(lib)],
                       max_batch=args.max_batch,
                       use_kernel=args.use_kernel,
                       buckets=not args.no_buckets)

    rng = np.random.default_rng(0)
    uniform = {d: 1.0 / 8 for d in corpus.tables}
    toks, doms = corpus.sample_mixture(uniform, args.requests, args.seq, rng)
    mb = mlm_batch(toks, rng, 0.15, corpus.vocab_size)
    flag_mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]
    for i in range(args.requests):
        eng.submit(Request(uid=i, tokens=mb["tokens"][i],
                           targets=mb["targets"][i], mask=mb["mask"][i],
                           lambdas=flag_mix[i % len(flag_mix)]))
    t0 = time.time()
    results = eng.run()
    dt = time.time() - t0
    accs = [r.accuracy for r in results if r.accuracy is not None]
    losses = [r.loss for r in results if r.loss is not None]
    print(json.dumps({
        "requests": len(results),
        "router_path": "fused-kernel" if args.use_kernel else "host",
        "wall_s": round(dt, 2),
        "req_per_s": round(len(results) / dt, 1),
        "mean_mlm_accuracy": round(float(np.mean(accs)), 4),
        "mean_mlm_loss": round(float(np.mean(losses)), 4),
        "engine": eng.stats.summary(),
    }, indent=1))


if __name__ == "__main__":
    main()
