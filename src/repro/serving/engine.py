"""The Tryage serving engine: batched router scoring -> constrained routing
-> per-expert micro-batched execution.

This is the production form of the paper's dispatch loop: requests queue
up, the perceptive router scores a whole batch in one forward pass, the
routing objective (with per-request lambda weights from user flags) picks
an expert per prompt, prompts are grouped into per-expert micro-batches and
executed, and results stream back with measured loss/accuracy plus a FLOPs
proxy for the cost/performance telemetry that the Pareto analysis consumes.

Two decision paths exist:

  use_kernel=True   one jit'd decision function per batch: the encoder
                    embedding runs in XLA, then MLP head -> softplus ->
                    lambda-weighted constraint add -> argmin run fused in
                    the Pallas kernel (``router_score_fused`` via
                    ``ops.router_route``), compiled on TPU/GPU, interpret
                    fallback on CPU.  No host round-trip between scoring
                    and selection.
  use_kernel=False  reference path: XLA head + NumPy constraint add on
                    the host (kept for parity checks and benchmarking).

Expert micro-batches are padded to power-of-two buckets (``buckets=True``)
so the jit'd expert functions see a bounded set of shapes instead of
recompiling for every ragged batch size; bucket occupancy is tracked in
``EngineStats``.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from collections import defaultdict
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.library import ModelLibrary
from repro.core.objective import Constraint, constraint_matrix
from repro.core.router import RouterConfig, predict_losses, router_embed
from repro.kernels.router_score import ops as rs_ops
from repro.models.model import forward
from repro.serving.requests import Request, Result, lambda_matrix


def bucket_size(n: int) -> int:
    """Smallest power of two >= n — the padded micro-batch shape."""
    return 1 << (n - 1).bit_length() if n > 1 else 1


@dataclasses.dataclass
class EngineStats:
    served: int = 0
    per_expert: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    total_flops: float = 0.0
    router_time_s: float = 0.0
    expert_time_s: float = 0.0
    # shape-bucketing telemetry: padded micro-batch size -> launch count,
    # plus the total number of padded (wasted) rows executed.
    bucket_hits: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    padded_rows: int = 0

    def summary(self) -> dict:
        return {"served": self.served,
                "per_expert": dict(self.per_expert),
                "total_flops": self.total_flops,
                "router_time_s": round(self.router_time_s, 3),
                "expert_time_s": round(self.expert_time_s, 3),
                "bucket_hits": {int(k): v for k, v in
                                sorted(self.bucket_hits.items())},
                "padded_rows": self.padded_rows}


class TryageEngine:
    def __init__(self, library: ModelLibrary, router_params,
                 rc: RouterConfig, constraints: Sequence[Constraint] = (),
                 max_batch: int = 16, use_kernel: bool = False,
                 interpret: bool | None = None, buckets: bool = True):
        assert len(library) == rc.n_models
        self.library = library
        self.router_params = router_params
        self.rc = rc
        self.constraints = list(constraints)
        self.max_batch = max_batch
        self.use_kernel = use_kernel
        self.buckets = buckets
        self.queue: list[Request] = []
        self.stats = EngineStats()

        self._cnames = [c.name for c in self.constraints]
        self._cmat = constraint_matrix(self.constraints, rc.n_models)

        if use_kernel:
            cmat = self._cmat

            def _decide(p, toks, lam):
                emb = router_embed(p, rc, {"tokens": toks})
                return rs_ops.router_route(emb, p["head"], cmat, lam,
                                           interpret=interpret)

            self._decide = jax.jit(_decide)
        else:
            self._score = jax.jit(
                lambda p, toks: predict_losses(p, rc, {"tokens": toks},
                                               use_kernel=False))
        self._expert_fns = {}
        for e in library.experts:
            self._expert_fns[e.name] = jax.jit(
                functools.partial(self._expert_forward, cfg=e.cfg))

    @staticmethod
    def _expert_forward(params, toks, targets, mask, *, cfg):
        """Per-example predictions, masked NLL and masked accuracy.

        Padded rows carry an all-zero mask, so their loss/accuracy reduce
        to 0 under the max(denominator, 1) guard and are dropped host-side.
        """
        logits, _, _ = forward(params, cfg, {"tokens": toks}, mode="train",
                               remat=False)
        logits = logits.astype(jnp.float32)
        preds = jnp.argmax(logits, axis=-1)
        # masked token NLL, one-hot contraction (see models.model.cross_entropy)
        logz = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
        gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
        m = mask.astype(jnp.float32)
        denom = jnp.maximum(m.sum(-1), 1.0)
        ex_loss = ((logz - gold) * m).sum(-1) / denom
        ex_acc = ((preds == targets) * m).sum(-1) / denom
        return preds, ex_loss, ex_acc

    # ------------------------------------------------------------- api

    def submit(self, req: Request):
        self.queue.append(req)

    def _bucket(self, n: int) -> int:
        return bucket_size(n) if self.buckets else n

    def _route_batch(self, reqs: list[Request]) -> tuple[np.ndarray,
                                                         np.ndarray]:
        """Route one batch of requests.

        Returns ``(pred_losses, choice)``: the router's predicted
        per-expert losses (B, M) f32 and the selected expert index (B,)
        int under each request's lambda-weighted constraints.
        """
        B = len(reqs)
        toks = np.stack([r.tokens for r in reqs])
        t0 = time.time()
        if self.use_kernel:
            # fused path: constraint add + argmin happen on-device inside
            # router_score_fused; pad to a bucket so the jit'd decision
            # function compiles once per bucket, not per ragged tail.
            lam = lambda_matrix(reqs, self._cnames)
            Bp = self._bucket(B)
            if Bp != B:
                toks = np.concatenate(
                    [toks, np.zeros((Bp - B,) + toks.shape[1:], toks.dtype)])
                lam = np.concatenate(
                    [lam, np.zeros((Bp - B, lam.shape[1]), lam.dtype)])
            pred, choice = self._decide(self.router_params,
                                        jnp.asarray(toks), jnp.asarray(lam))
            pred = np.asarray(pred)[:B]
            choice = np.asarray(choice)[:B]
        else:
            pred = np.asarray(
                self._score(self.router_params, jnp.asarray(toks)))
            # score = L-hat + sum_j lambda_j C_j, argmin on the host
            scores = pred.copy()
            for c in self.constraints:
                lam = np.array([r.lambdas.get(c.name, 0.0) for r in reqs])
                scores = scores + lam[:, None] * c.values[None, :]
            choice = scores.argmin(axis=1)
        self.stats.router_time_s += time.time() - t0
        return pred, choice

    def _run_expert(self, e, reqs: list[Request]):
        """Execute one padded per-expert micro-batch; returns per-example
        (preds, loss, acc) arrays trimmed back to len(reqs)."""
        n = len(reqs)
        Bp = self._bucket(n)
        S = len(reqs[0].tokens)
        toks = np.zeros((Bp, S), reqs[0].tokens.dtype)
        targets = np.zeros((Bp, S), np.int32)
        mask = np.zeros((Bp, S), np.int32)
        for j, r in enumerate(reqs):
            toks[j] = r.tokens
            if r.targets is not None:
                targets[j] = r.targets
            if r.mask is not None:
                mask[j] = r.mask
        preds, ex_loss, ex_acc = self._expert_fns[e.name](
            e.params, jnp.asarray(toks), jnp.asarray(targets),
            jnp.asarray(mask))
        self.stats.bucket_hits[Bp] += 1
        self.stats.padded_rows += Bp - n
        return (np.asarray(preds)[:n], np.asarray(ex_loss)[:n],
                np.asarray(ex_acc)[:n])

    def run(self) -> list[Result]:
        """Drain the queue; returns one Result per request."""
        results: list[Result] = []
        while self.queue:
            batch, self.queue = (self.queue[:self.max_batch],
                                 self.queue[self.max_batch:])
            pred, choice = self._route_batch(batch)
            by_expert: dict[int, list[int]] = defaultdict(list)
            for i, c in enumerate(choice):
                by_expert[int(c)].append(i)
            for mi, idxs in sorted(by_expert.items()):
                e = self.library[mi]
                t0 = time.time()
                preds, ex_loss, ex_acc = self._run_expert(
                    e, [batch[i] for i in idxs])
                dt = time.time() - t0
                self.stats.expert_time_s += dt
                for j, i in enumerate(idxs):
                    r = batch[i]
                    loss = acc = None
                    if (r.targets is not None and r.mask is not None
                            and r.mask.astype(bool).any()):
                        loss = float(ex_loss[j])
                        acc = float(ex_acc[j])
                    flops = 2.0 * e.n_params * len(r.tokens)
                    results.append(Result(
                        uid=r.uid, expert=e.name, pred_losses=pred[i],
                        predictions=preds[j], loss=loss, accuracy=acc,
                        flops_proxy=flops, latency_s=dt / max(len(idxs), 1)))
                    self.stats.served += 1
                    self.stats.per_expert[e.name] += 1
                    self.stats.total_flops += flops
        return results
