"""The Tryage serving engine: an explicit staged pipeline

    Route -> Cascade -> Execute -> Feedback

over a model library (stages in ``repro.serving.pipeline``).

This is the production form of the paper's dispatch loop: requests are
admitted, the perceptive router scores a whole admission batch in one
forward pass (Route), the routing objective (with per-request lambda
weights from user flags) picks an expert per prompt and the
confidence cascade may escalate it (Cascade), and prompts land in
per-expert *lanes* owned by the scheduler; lane flushes run the expert
(Execute) and publish the observed loss back to the router's replay
buffer (Feedback).  Two executor disciplines exist on top of the same
routing stages:

  ``run()``    FIFO drain — every admission batch launches its per-expert
               groups immediately, however ragged.  Kept as the baseline
               the continuous-batching path is benchmarked against.
  ``serve()``  continuous batching — lanes accumulate same-expert
               requests *across* admission batches and flush only on a
               full power-of-two bucket or a ``max_wait_s`` deadline
               (see ``repro.serving.scheduler``), streaming ``Result``s
               back as micro-batches complete.

Routing decisions are memoised in an exact LRU cache keyed on
``(token bytes, lambda vector, confidence threshold, router version)``
(``repro.serving.cache``), so repeated prompts skip the router forward
pass entirely; a hit returns the identical (post-cascade) verdict the
fresh score produced, and a router-version bump makes every older
verdict unreachable.

Confidence-aware cascade: a request may carry ``min_confidence > 0``.
After scoring, the router's per-expert uncertainty head (constant prior
for pre-cascade checkpoints) yields a calibrated confidence per expert;
if the chosen expert's confidence is below the threshold, the request
is *escalated* — re-enqueued into the scheduler's escalation lane for
the next-larger expert (``core.objective.cascade_choice``, bounded
depth, cycle-safe) instead of flushing with its first pick.  Cascade
telemetry (escalations, depth histogram, per-tier latency) lands in
``EngineStats``.  ``min_confidence = 0`` (the default) is single-shot:
the sigma pass is skipped entirely and behaviour is identical to the
pre-cascade engine.

Online adaptation: the paper's router *continually tracks downstream
expert performance*, so the engine can close the loop at serving time.
Expert execution already measures the chosen expert's true masked NLL;
the Feedback stage publishes those (prompt, expert, loss) samples onto
a bounded replay buffer (``repro.serving.feedback``), and every
``adapt_every`` samples the engine replays a batch through the jit'd
incremental update built by ``core.training.make_router_update_step``
on *shadow weights* — in-flight scoring keeps reading the complete old
tree, and
the refreshed parameters are published atomically via
``core.router.VersionedParams.swap``.  Each swap bumps the router
``version``, which is part of the decision-cache key, so verdicts
scored by a superseded router are structurally unreachable (the cache
is also cleared to reclaim their memory).  ``adapt_every=0`` (the
default) freezes the router and the engine behaves exactly like the
pre-adaptation engine, bit-for-bit.

Two decision paths exist for the scoring itself:

  use_kernel=True   one jit'd decision function per batch: the encoder
                    embedding runs in XLA, then MLP head -> softplus ->
                    lambda-weighted constraint add -> argmin run fused in
                    the Pallas kernel (``router_score_fused`` via
                    ``ops.router_route``), compiled on TPU/GPU, interpret
                    fallback on CPU.  No host round-trip between scoring
                    and selection.
  use_kernel=False  reference path: XLA head + NumPy constraint add on
                    the host (kept for parity checks and benchmarking).

Expert micro-batches are padded to power-of-two buckets (``buckets=True``)
so the jit'd expert functions see a bounded set of shapes instead of
recompiling for every ragged batch size; bucket occupancy, flush
reasons, cache hit rate and per-request latency percentiles are tracked
in ``EngineStats``.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import time
from collections import defaultdict, deque
from typing import Callable, Iterable, Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.library import ModelLibrary
from repro.core.objective import (Constraint, cascade_choice,
                                  confidence_scores, constraint_matrix,
                                  escalation_order, fallback_choice)
from repro.core.router import (RouterConfig, VersionedParams,
                               losses_from_emb, predict_losses,
                               predict_uncertainty, router_embed)
from repro.core.training import (make_router_update_step,
                                 router_prediction_error)
from repro.kernels import sanitize
from repro.kernels.router_cascade import ops as rc_ops
from repro.kernels.router_score import ops as rs_ops
from repro.models.model import forward
from repro.serving.cache import DecisionCache, DecisionCacheStack
from repro.serving.semcache import SemanticCache
from repro.serving.feedback import ReplayBuffer
from repro.serving.health import ExpertHealth
from repro.serving.pipeline import RouteContext, ServingPipeline
from repro.serving.placement import (PlacementMap, StreamClock,
                                     plan_placement)
from repro.serving.requests import Request, Result, lambda_matrix
from repro.serving.scheduler import ExpertScheduler, LaneEntry
from repro.sharding.context import (activation_sharding, batch_sharding,
                                    replicated_sharding)
from repro.sharding.rules import DEFAULT_RULES


def bucket_size(n: int) -> int:
    """Smallest power of two >= n — the padded micro-batch shape."""
    return 1 << (n - 1).bit_length() if n > 1 else 1


@dataclasses.dataclass
class EngineStats:
    served: int = 0
    per_expert: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    total_flops: float = 0.0
    router_time_s: float = 0.0
    router_batches: int = 0            # router forward passes launched
    expert_time_s: float = 0.0
    # shape-bucketing telemetry: padded micro-batch size -> launch count,
    # plus the total number of padded (wasted) rows executed.
    bucket_hits: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    padded_rows: int = 0
    # scheduler telemetry: flush reason -> count, peak lane depth per
    # expert name, and true enqueue->flush latency per request.
    flushes: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    lane_peaks: dict = dataclasses.field(default_factory=dict)
    # bounded window so a long-running serve() keeps O(1) memory;
    # percentiles are over the most recent 64k requests
    latencies: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=65536))
    # router-decision cache telemetry.  Tier attribution: "t1" is the
    # in-process exact LRU, "t2" the persistent KV store, "t3" the
    # semantic tier.  Revalidations count semantic candidates found
    # within the distance bound (then version-checked); rejects are the
    # candidates that failed the check (stale router version).
    # cache_key_dropped_lambda counts request lambda flags whose names
    # matched no engine constraint (dropped from the cache key, and
    # from scoring, by design — the count makes the typo visible).
    cache_hits: int = 0
    cache_misses: int = 0
    cache_tier_hits: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    cache_revalidations: int = 0
    cache_revalidation_rejects: int = 0
    cache_key_dropped_lambda: int = 0
    # cascade telemetry: escalated-request count, histogram of cascade
    # depth over all served requests (depth 0 = first pick), and true
    # enqueue->flush latency bucketed by cascade tier.
    escalations: int = 0
    cascade_depth_hist: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    tier_latencies: dict = dataclasses.field(
        default_factory=lambda: defaultdict(
            lambda: deque(maxlen=65536)))
    # speculative-escalation telemetry (serve() with speculate=True):
    # lane entries enqueued before their escalation verdict resolved,
    # split into confirmed first picks (hits), entries pulled back out
    # of their lane before flushing (cancelled), and entries whose
    # speculative execution had to be discarded (wasted, with the token
    # count of the discarded work).  Exactly-once invariant:
    # launched == hits + cancelled + wasted once all verdicts resolve.
    spec_launched: int = 0
    spec_hits: int = 0
    spec_cancelled: int = 0
    spec_wasted: int = 0
    spec_wasted_tokens: int = 0
    # effective launch geometry of the fused decision kernel per padded
    # admission-batch size (the tile that actually ran after the
    # block_b = min(block_b, B) clamp — summary/debug only)
    router_tiles: dict = dataclasses.field(default_factory=dict)
    # online-adaptation telemetry: router updates applied (and the
    # resulting router version), feedback samples published, replay
    # occupancy, wall time spent in update steps, and the mean
    # |L-hat[chosen] - L_observed| on the last replayed batch before and
    # after its update (the adaptation loop's health signal: post < pre
    # means the update moved predictions toward observed reality).
    adapt_updates: int = 0
    router_version: int = 0
    feedback_events: int = 0
    feedback_dropped: int = 0
    replay_len: int = 0
    replay_cap: int = 0
    adapt_time_s: float = 0.0
    adapt_pre_err: float = 0.0
    adapt_post_err: float = 0.0
    # serving-front-end telemetry: concurrent sessions multiplexed, total
    # requests admitted through the bounded queue, load-shed requests
    # (total and per Request.priority), and the queue's peak occupancy.
    sessions: int = 0
    admitted: int = 0
    shed: int = 0
    shed_by_priority: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    admission_queue_peak: int = 0
    # health-fallback telemetry: route-time fallback re-selections (with
    # a depth histogram and the graceful-degraded subset), failed-flush
    # re-routes, requests failed outright (no fallback available), and
    # failed flushes per expert name.
    fallbacks: int = 0
    fallback_depth_hist: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    degraded: int = 0
    reroutes: int = 0
    failed: int = 0
    expert_failures: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    @staticmethod
    def _pctiles(latencies) -> dict:
        if not latencies:
            return {"p50_s": 0.0, "p95_s": 0.0}
        lat = np.asarray(latencies)
        return {"p50_s": float(np.percentile(lat, 50)),
                "p95_s": float(np.percentile(lat, 95))}

    def latency_percentiles(self) -> dict:
        return self._pctiles(self.latencies)

    def tier_latency_percentiles(self) -> dict:
        """p50/p95 enqueue->flush latency per cascade tier (depth)."""
        return {int(tier): self._pctiles(lat)
                for tier, lat in sorted(self.tier_latencies.items())}

    def summary(self) -> dict:
        return {"served": self.served,
                "per_expert": dict(self.per_expert),
                "total_flops": self.total_flops,
                "router_time_s": round(self.router_time_s, 3),
                "router_batches": self.router_batches,
                "expert_time_s": round(self.expert_time_s, 3),
                "bucket_hits": {int(k): v for k, v in
                                sorted(self.bucket_hits.items())},
                "padded_rows": self.padded_rows,
                "flushes": dict(self.flushes),
                "lane_peaks": dict(self.lane_peaks),
                "latency": {k: round(v, 6) for k, v in
                            self.latency_percentiles().items()},
                "cache": {"hits": self.cache_hits,
                          "misses": self.cache_misses,
                          "hit_rate": round(self.cache_hit_rate, 4),
                          "tiers": {k: int(v) for k, v in
                                    sorted(self.cache_tier_hits.items())},
                          "revalidations": self.cache_revalidations,
                          "revalidation_rejects":
                              self.cache_revalidation_rejects,
                          "dropped_lambda":
                              self.cache_key_dropped_lambda},
                "cascade": {
                    "escalations": self.escalations,
                    "depth_hist": {int(k): v for k, v in
                                   sorted(self.cascade_depth_hist.items())},
                    "tier_latency": {
                        tier: {k: round(v, 6) for k, v in p.items()}
                        for tier, p in
                        self.tier_latency_percentiles().items()}},
                "speculation": {
                    "launched": self.spec_launched,
                    "hits": self.spec_hits,
                    "cancelled": self.spec_cancelled,
                    "wasted": self.spec_wasted,
                    "wasted_tokens": self.spec_wasted_tokens},
                "router_tiles": {int(k): dict(v) for k, v in
                                 sorted(self.router_tiles.items())},
                "adaptation": {
                    "updates": self.adapt_updates,
                    "router_version": self.router_version,
                    "feedback_events": self.feedback_events,
                    "feedback_dropped": self.feedback_dropped,
                    "replay": {"len": self.replay_len,
                               "cap": self.replay_cap},
                    "pre_err": round(self.adapt_pre_err, 6),
                    "post_err": round(self.adapt_post_err, 6),
                    "time_s": round(self.adapt_time_s, 3)},
                "frontend": {
                    "sessions": self.sessions,
                    "admitted": self.admitted,
                    "shed": self.shed,
                    "shed_by_priority": {int(k): v for k, v in
                                         sorted(self.shed_by_priority
                                                .items())},
                    "queue_peak": self.admission_queue_peak},
                "fallback": {
                    "fallbacks": self.fallbacks,
                    "depth_hist": {int(k): v for k, v in
                                   sorted(self.fallback_depth_hist
                                          .items())},
                    "degraded": self.degraded,
                    "reroutes": self.reroutes,
                    "failed": self.failed,
                    "expert_failures": dict(self.expert_failures)}}


class TryageEngine:
    """Staged serving pipeline (Route -> Cascade -> Execute -> Feedback)
    over a model library.

    Scheduler knobs (used by ``serve()``):

    - ``lane_target``: lane occupancy that flushes a full micro-batch;
      defaults to ``bucket_size(max_batch)`` so a target flush is a full
      power-of-two bucket with zero padded rows.
    - ``max_wait_s``: deadline for the oldest request in a lane — a lane
      holding even a single request flushes once it has waited this long.
    - ``decision_cache`` / ``cache_capacity``: exact LRU memoisation of
      routing decisions keyed on (token bytes, lambda vector,
      confidence threshold, router version).
    - ``cache_kv`` / ``cache_dir``: persistent exact cache tier (T2)
      behind the Valkey-shaped KV interface (``serving.kvstore``) —
      inject a store, or point ``cache_dir`` at a directory for the
      crash-safe disk default.  Restart-safe: same dir + same router
      version = warm cache.
    - ``cache_semantic_eps`` / ``cache_semantic_cap``: approximate
      cache tier (T3) keyed on router embeddings; ``eps > 0`` enables
      it (calibrate with ``serving.semcache.calibrate_eps`` or
      ``bench_cache``).  Verdicts are revalidated against the live
      router version before use.
    - ``cascade_max_depth``: bound on escalation steps per request; 0
      disables the cascade engine-wide regardless of request thresholds.
    - ``fused_cascade``: resolve scoring, confidence and the depth-1
      escalation verdict in ONE kernel launch
      (``kernels.router_cascade``) for batches that carry cascade
      traffic.  Needs ``use_kernel=True``, an uncertainty head on the
      router params, and a single-data-shard engine; otherwise (and for
      batches with no confidence floors) the staged path runs
      unchanged, so the flag degrades to a no-op instead of an error.
      Depth >= 2 escalations fall back to the staged host walk row by
      row, so verdicts match the staged path by construction.
    - ``speculate``: in ``serve()``, enqueue each cascade-eligible
      request's *first pick* lane entry immediately and resolve the
      escalation verdict on the next scheduler tick — lane occupancy
      and deadline clocks see the request while its verdict is in
      flight.  On escalate the entry is cancelled out of its lane (or
      its already-executed speculative result is discarded and counted
      as wasted) and re-laned to the escalation target.  Exactly-once:
      every request still yields exactly one Result.  Ignored when a
      health tracker is attached (fallback must see final choices) and
      under ``run()``.  Off (the default) is byte-identical to the
      non-speculative engine.
    - ``now_fn``: engine clock (injectable for deterministic tests).

    Online-adaptation knobs (used by the Feedback stage):

    - ``adapt_every``: feedback samples between router updates; 0 (the
      default) freezes the router — no updates, ever.
    - ``adapt_lr`` / ``adapt_ema`` / ``adapt_batch`` /
      ``adapt_trainable``: the incremental update recipe (see
      ``core.training.make_router_update_step``); ``"head"`` adapts the
      loss head only (the stable default), ``"all"`` also fine-tunes
      the encoder.
    - ``replay_cap``: bounded replay-buffer capacity; 0 disables
      feedback collection entirely.
    """

    def __init__(self, library: ModelLibrary, router_params,
                 rc: RouterConfig, constraints: Sequence[Constraint] = (),
                 max_batch: int = 16, use_kernel: bool = False,
                 interpret: bool | None = None, buckets: bool = True,
                 lane_target: int | None = None, max_wait_s: float = 0.05,
                 decision_cache: bool = True, cache_capacity: int = 4096,
                 cache_kv=None, cache_dir: str | None = None,
                 cache_semantic_eps: float = 0.0,
                 cache_semantic_cap: int = 65536,
                 cascade_max_depth: int = 2,
                 fused_cascade: bool = False, speculate: bool = False,
                 adapt_every: int = 0, adapt_lr: float = 1e-2,
                 adapt_ema: float = 0.0, adapt_batch: int = 32,
                 adapt_trainable: str = "head", replay_cap: int = 4096,
                 adapt_seed: int = 0,
                 health: ExpertHealth | None = None,
                 fallback_max_depth: int = 2,
                 mesh=None, placement: PlacementMap | None = None,
                 replicate_hot: int = 0,
                 now_fn: Callable[[], float] = time.monotonic):
        assert len(library) == rc.n_models
        if health is not None:
            assert health.n_experts == len(library), \
                "health tracker sized for a different library"
        self.library = library
        # the served router is a versioned snapshot: online adaptation
        # computes new weights off to the side and publishes them with
        # an atomic swap that bumps the version (and the cache keys)
        self._router = VersionedParams(router_params, 0)
        self.rc = rc
        self.constraints = list(constraints)
        self.max_batch = max_batch
        self.use_kernel = use_kernel
        self.buckets = buckets
        self.lane_target = (bucket_size(max_batch) if lane_target is None
                            else lane_target)
        self.max_wait_s = max_wait_s
        # decision cache: exact-only traffic gets the plain LRU (the
        # pre-stack engine, bit-for-bit); enabling the persistent or
        # semantic tier builds the stack.  cache_kv injects a KVStore
        # (e.g. a shared MemoryKVStore across replicas, or a real
        # Valkey adapter); cache_dir builds the crash-safe DiskKVStore.
        if decision_cache:
            kv = cache_kv
            if kv is None and cache_dir is not None:
                from repro.serving.kvstore import DiskKVStore
                kv = DiskKVStore(cache_dir)
            sem = (SemanticCache(cache_semantic_eps, cache_semantic_cap)
                   if cache_semantic_eps > 0.0 else None)
            if kv is not None or sem is not None:
                self.cache = DecisionCacheStack(cache_capacity, kv=kv,
                                                semantic=sem)
            else:
                self.cache = DecisionCache(cache_capacity)
        else:
            self.cache = None
        self.cascade_max_depth = cascade_max_depth
        self.fused_cascade = fused_cascade
        self.speculate = speculate
        self._esc_order = escalation_order(library)
        # expert index -> position in the escalation ladder (the inverse
        # permutation the fused cascade kernel consumes)
        self._ladder_pos = np.zeros(len(library), np.int64)
        for pos, e in enumerate(self._esc_order):
            self._ladder_pos[e] = pos
        # per-expert health/overload tracker (None = health-unaware
        # engine, the fallback stage is a strict no-op) and the bound on
        # route-time fallback re-selections per request
        self.health = health
        self.fallback_max_depth = fallback_max_depth
        # live ExpertScheduler while serve() runs (failure-injection
        # handle for tests/benchmarks); None outside serve()
        self.scheduler: ExpertScheduler | None = None
        self._now = now_fn
        self.queue: list[Request] = []
        self.stats = EngineStats()

        # online adaptation: replay buffer + jit'd incremental update.
        # The buffer fills whenever feedback is available (telemetry and
        # offline analysis want it even for a frozen router); updates
        # only happen when adapt_every > 0.
        if adapt_every < 0 or adapt_batch < 1:
            raise ValueError("adapt_every must be >= 0 and "
                             "adapt_batch >= 1")
        if adapt_every > 0 and replay_cap <= 0:
            raise ValueError("adapt_every > 0 needs a replay buffer "
                             "(replay_cap >= 1)")
        self.adapt_every = adapt_every
        self.adapt_batch = adapt_batch
        self.replay = ReplayBuffer(replay_cap) if replay_cap > 0 else None
        self._adapt_rng = np.random.default_rng(adapt_seed)
        self._fb_at_last_update = 0
        if adapt_every > 0:
            self._update_step = make_router_update_step(
                rc, lr=adapt_lr, ema=adapt_ema, trainable=adapt_trainable)

            def _adapt_step(p, t, e, o):
                # pre/post prediction error fused with the update into
                # one jit'd program: one device->host pull per adaptation
                # step instead of two blocking float() syncs (JXL001)
                pre = router_prediction_error(p, rc, t, e, o)
                new_p, _ = self._update_step(p, t, e, o)
                post = router_prediction_error(new_p, rc, t, e, o)
                return new_p, jnp.stack([pre, post])

            self._adapt_step = jax.jit(_adapt_step)

        # the staged pipeline: Route -> Cascade (admission half) and
        # Execute -> Feedback (flush half), composed over this engine's
        # jit'd primitives
        self.pipeline = ServingPipeline(self)

        self._cnames = [c.name for c in self.constraints]
        self._cmat = constraint_matrix(self.constraints, rc.n_models)

        # lazy sigma pass: only cascade-enabled requests pay for it, so
        # the min_confidence=0 path runs the exact pre-cascade jits
        self._sigma = jax.jit(
            lambda p, toks: predict_uncertainty(p, rc, {"tokens": toks}))

        # semantic-tier path: pooled embedding and head-from-embedding
        # jits, compiled only if the semantic cache tier is enabled (the
        # T3 probe needs the embedding before it knows whether a fresh
        # score is needed, so the score is split at the embedding)
        self._embed = jax.jit(
            lambda p, toks: router_embed(p, rc, {"tokens": toks}))
        self._head_from_emb = jax.jit(
            lambda p, emb: losses_from_emb(p["head"], emb))

        if use_kernel:
            cmat = self._cmat

            def _decide(p, toks, lam):
                emb = router_embed(p, rc, {"tokens": toks})
                return rs_ops.router_route(emb, p["head"], cmat, lam,
                                           interpret=interpret)

            self._decide = jax.jit(_decide)
            if fused_cascade:
                ladder = jnp.asarray(self._ladder_pos, jnp.int32)

                def _decide_cascade(p, toks, lam):
                    emb = router_embed(p, rc, {"tokens": toks})
                    return rc_ops.router_route_cascade(
                        emb, p["head"], p["unc"], cmat, lam, ladder,
                        interpret=interpret)

                self._decide_cascade = jax.jit(_decide_cascade)
        else:
            self._score = jax.jit(
                lambda p, toks: predict_losses(p, rc, {"tokens": toks},
                                               use_kernel=False))
        self._expert_fns = {}
        self._expert_idx = {}
        for i, e in enumerate(library.experts):
            self._expert_fns[e.name] = jax.jit(
                functools.partial(self._expert_forward, cfg=e.cfg))
            self._expert_idx[e.name] = i

        # ------------------------------------------------ mesh wiring
        # A (data, model) mesh makes the pipeline multi-device: the
        # routing stage shards admission batches over the "data" axis,
        # and the Execute stage places each expert on a "model"-axis
        # slice (serving.placement) so lane flushes land in per-device
        # streams that overlap instead of serializing on device 0.
        # mesh=None (the default) is the single-device engine,
        # bit-for-bit — none of the fields below are consulted.
        self.mesh = mesh
        self.placement: PlacementMap | None = None
        self.streams: StreamClock | None = None
        self._data_ext = 1
        self._mesh_rp_cache: tuple[int, object] | None = None
        if mesh is not None:
            missing = {"data", "model"} - set(mesh.axis_names)
            if missing:
                raise ValueError(f"serving mesh needs axes "
                                 f"('data', 'model'); missing {missing}")
            self._data_ext = int(mesh.shape["data"])
            model_ext = int(mesh.shape["model"])
            if placement is None:
                placement = plan_placement(
                    [e.n_params for e in library.experts], model_ext,
                    replicate_hot=replicate_hot)
            if placement.n_slices != model_ext:
                raise ValueError(f"placement has {placement.n_slices} "
                                 f"slices but the mesh's model axis is "
                                 f"{model_ext}")
            if placement.n_experts != len(library):
                raise ValueError("placement sized for a different library")
            self.placement = placement
            # device grid (data, model): slice k owns column k; stream
            # index == flat device index r * model_ext + k
            grid = np.asarray(mesh.devices).reshape(self._data_ext,
                                                    model_ext)
            self._devices = list(grid.reshape(-1))
            self.streams = StreamClock(len(self._devices))
            self._expert_streams = {
                i: [r * model_ext + k
                    for k in placement.slices_for(i)
                    for r in range(self._data_ext)]
                for i in range(len(library))}
            # per-(expert, stream) committed parameter replicas, filled
            # lazily on first dispatch so unused replicas cost nothing
            self._expert_params_on: dict[tuple[int, int], object] = {}
            if self._data_ext > 1:
                if use_kernel:
                    # GSPMD cannot partition pallas_call, so the fused
                    # decision runs under shard_map: per-device blocks
                    # of the batch through the same kernel, params
                    # replicated (P() spec)
                    from jax.experimental.shard_map import shard_map
                    from jax.sharding import PartitionSpec as P
                    cmat = self._cmat

                    def _decide_sharded(p, toks, lam):
                        emb = router_embed(p, rc, {"tokens": toks})
                        return rs_ops.router_route(emb, p["head"], cmat,
                                                   lam,
                                                   interpret=interpret)

                    self._decide_mesh = jax.jit(shard_map(
                        _decide_sharded, mesh=mesh,
                        in_specs=(P(), P("data", None), P("data", None)),
                        out_specs=(P("data", None), P("data")),
                        check_rep=False))
                else:
                    # GSPMD path: same predict_losses program, traced
                    # under the activation-sharding context so
                    # shard_act pins the batch axis through the encoder
                    self._score_mesh = jax.jit(
                        lambda p, toks: predict_losses(
                            p, rc, {"tokens": toks}, use_kernel=False))

    def _mesh_router_params(self):
        """Router params replicated onto the serving mesh, re-put only
        when adaptation swaps the version (device transfer once per
        snapshot, not once per batch)."""
        if (self._mesh_rp_cache is None
                or self._mesh_rp_cache[0] != self.router_version):
            rp = jax.device_put(self.router_params,
                                replicated_sharding(self.mesh))
            self._mesh_rp_cache = (self.router_version, rp)
        return self._mesh_rp_cache[1]

    def mesh_summary(self) -> dict | None:
        """Placement + per-device stream telemetry (None without a
        mesh).  Deliberately *not* part of ``EngineStats`` — the
        1x1-mesh engine must stay bit-for-bit identical to the meshless
        engine, EngineStats included."""
        if self.mesh is None:
            return None
        names = [e.name for e in self.library.experts]
        return {
            "mesh": {k: int(v) for k, v in self.mesh.shape.items()},
            "placement": self.placement.summary(names),
            "streams": self.streams.summary(),
        }

    def warm_mesh(self, seq_len: int,
                  bucket_sizes: Sequence[int] | None = None) -> int:
        """Pre-place every expert replica and pre-compile every
        (expert, replica device, bucket size) execution variant.

        Flush dispatch picks the least-busy replica stream at flush
        time, so which (expert, device) variants a warm *serving* pass
        touches depends on wall-clock timings — a later flush can land
        on a device whose program was never compiled and eat the
        compile inside measured traffic.  Serving drivers and
        ``bench_mesh`` call this once up front instead; it is a no-op
        (returns 0) without a mesh.  Streams are not charged — warming
        is not traffic."""
        if self.placement is None:
            return 0
        if bucket_sizes is None:
            bucket_sizes = [b for b in (1, 2, 4, 8, 16, 32, 64, 128)
                            if b <= self.lane_target] or [self.lane_target]
        compiled = 0
        for ei, streams in self._expert_streams.items():
            e = self.library[ei]
            fn = self._expert_fns[e.name]
            for slot in streams:
                dev = self._devices[slot]
                key = (ei, slot)
                ep = self._expert_params_on.get(key)
                if ep is None:
                    ep = jax.device_put(e.params, dev)
                    self._expert_params_on[key] = ep
                for b in bucket_sizes:
                    zi = np.zeros((b, seq_len), np.int32)
                    preds, _, _ = fn(ep, jax.device_put(zi, dev),
                                     jax.device_put(zi, dev),
                                     jax.device_put(zi, dev))
                    jax.block_until_ready(preds)
                    compiled += 1
        return compiled

    @property
    def router_params(self):
        """The live router snapshot's parameter tree (read-only view;
        adaptation publishes new trees via ``VersionedParams.swap``)."""
        return self._router.params

    @property
    def router_version(self) -> int:
        """Monotone version of the live router snapshot — part of every
        decision-cache key."""
        return self._router.version

    @staticmethod
    def _expert_forward(params, toks, targets, mask, *, cfg):
        """Per-example predictions, masked NLL and masked accuracy.

        Padded rows carry an all-zero mask, so their loss/accuracy reduce
        to 0 under the max(denominator, 1) guard and are dropped host-side.
        """
        logits, _, _ = forward(params, cfg, {"tokens": toks}, mode="train",
                               remat=False)
        logits = logits.astype(jnp.float32)
        preds = jnp.argmax(logits, axis=-1)
        # masked token NLL, one-hot contraction (see models.model.cross_entropy)
        logz = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
        gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
        m = mask.astype(jnp.float32)
        denom = jnp.maximum(m.sum(-1), 1.0)
        ex_loss = ((logz - gold) * m).sum(-1) / denom
        ex_acc = ((preds == targets) * m).sum(-1) / denom
        return preds, ex_loss, ex_acc

    # ------------------------------------------------------------- api

    def submit(self, req: Request):
        if req.arrival is None:
            req.arrival = self._now()
        self.queue.append(req)

    def _bucket(self, n: int) -> int:
        return bucket_size(n) if self.buckets else n

    # ---------------------------------------------------- routing stage

    def _score_batch(self, reqs: list[Request]) -> tuple[np.ndarray,
                                                         np.ndarray]:
        """Score one batch with the router (no cache).

        Returns ``(pred_losses, choice)``: the router's predicted
        per-expert losses (B, M) f32 and the selected expert index (B,)
        int under each request's lambda-weighted constraints.
        """
        B = len(reqs)
        toks = np.stack([r.tokens for r in reqs])
        t0 = self._now()
        data_par = self._data_ext > 1
        if self.use_kernel:
            # fused path: constraint add + argmin happen on-device inside
            # router_score_fused; pad to a bucket so the jit'd decision
            # function compiles once per bucket, not per ragged tail.
            lam = lambda_matrix(reqs, self._cnames)
            Bp = self._bucket(B)
            if data_par and Bp % self._data_ext:
                # shard_map needs the batch divisible by the data axis
                Bp += self._data_ext - Bp % self._data_ext
            if Bp != B:
                toks = np.concatenate(
                    [toks, np.zeros((Bp - B,) + toks.shape[1:], toks.dtype)])
                lam = np.concatenate(
                    [lam, np.zeros((Bp - B, lam.shape[1]), lam.dtype)])
            if data_par:
                # data-parallel decision: batch rows sharded over the
                # mesh's "data" axis, params replicated, the same fused
                # kernel per device block (shard_map — see __init__)
                bs = batch_sharding(self.mesh, 2, toks.shape)
                pred, choice = self._decide_mesh(
                    self._mesh_router_params(),
                    jax.device_put(toks, bs),
                    jax.device_put(lam, batch_sharding(self.mesh, 2,
                                                       lam.shape)))
            else:
                pred, choice = self._decide(self.router_params,
                                            jnp.asarray(toks),
                                            jnp.asarray(lam))
            if Bp not in self.stats.router_tiles:
                # effective tile actually launched for this padded batch
                # (block_b silently clamps to the batch — see
                # kernels.router_score.kernel.launch_plan)
                self.stats.router_tiles[Bp] = rs_ops.decision_plan(Bp)
            if sanitize.sanitize_enabled():
                self._sanitize_batch(toks, pred, choice)
            pred = np.asarray(pred)[:B]
            choice = np.asarray(choice)[:B]
        else:
            if data_par:
                Bp = B
                if Bp % self._data_ext:
                    Bp += self._data_ext - Bp % self._data_ext
                    toks = np.concatenate(
                        [toks,
                         np.zeros((Bp - B,) + toks.shape[1:], toks.dtype)])
                # GSPMD data-parallel scoring: inputs NamedSharding'd by
                # batch (sharding/rules.py "batch" -> "data"), traced
                # under the activation-sharding context so the encoder
                # keeps the batch axis sharded end to end
                tsh = jax.device_put(toks,
                                     batch_sharding(self.mesh, 2,
                                                    toks.shape))
                with activation_sharding(self.mesh, DEFAULT_RULES):
                    pred_dev = self._score_mesh(self._mesh_router_params(),
                                                tsh)
                if sanitize.sanitize_enabled():
                    self._sanitize_batch(toks, pred_dev)
                pred = np.asarray(pred_dev)[:B]
            else:
                pred_dev = self._score(self.router_params,
                                       jnp.asarray(toks))
                if sanitize.sanitize_enabled():
                    self._sanitize_batch(toks, pred_dev)
                pred = np.asarray(pred_dev)
            # score = L-hat + sum_j lambda_j C_j, argmin on the host
            scores = pred.copy()
            for c in self.constraints:
                lam = np.array([r.lambdas.get(c.name, 0.0) for r in reqs])
                scores = scores + lam[:, None] * c.values[None, :]
            choice = scores.argmin(axis=1)
        self.stats.router_time_s += self._now() - t0
        self.stats.router_batches += 1
        return pred, choice

    def _use_fused_cascade(self, reqs: list[Request]) -> bool:
        """Whether this batch takes the one-launch cascade decision:
        the flag is on, the kernel path is active, the router carries an
        uncertainty head, the cascade is enabled, the engine is not
        data-sharded (shard_map wiring covers the plain kernel only),
        and the batch actually contains cascade traffic.  Batches that
        fail any gate run the staged path bit-for-bit."""
        return (self.fused_cascade and self.use_kernel
                and self.cascade_max_depth > 0
                and self._data_ext == 1
                and "unc" in self.router_params
                and any(r.min_confidence > 0.0 for r in reqs))

    def _score_cascade_batch(self, reqs: list[Request]) -> tuple[
            np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """One-launch cascade scoring: predicted losses, per-expert
        sigma, constrained first pick and the router-preferred depth-1
        escalation target, all from a single fused kernel launch
        (``kernels.router_cascade``).  Mirrors ``_score_batch``'s
        bucket padding and telemetry."""
        B = len(reqs)
        toks = np.stack([r.tokens for r in reqs])
        lam = lambda_matrix(reqs, self._cnames)
        t0 = self._now()
        Bp = self._bucket(B)
        if Bp != B:
            toks = np.concatenate(
                [toks, np.zeros((Bp - B,) + toks.shape[1:], toks.dtype)])
            lam = np.concatenate(
                [lam, np.zeros((Bp - B, lam.shape[1]), lam.dtype)])
        pred, sigma, choice, esc = self._decide_cascade(
            self.router_params, jnp.asarray(toks), jnp.asarray(lam))
        if Bp not in self.stats.router_tiles:
            self.stats.router_tiles[Bp] = rc_ops.decision_plan(Bp)
        if sanitize.sanitize_enabled():
            self._sanitize_batch(toks, pred, choice)
        pred = np.asarray(pred)[:B]
        sigma = np.asarray(sigma)[:B]
        choice = np.asarray(choice)[:B]
        esc = np.asarray(esc)[:B]
        self.stats.router_time_s += self._now() - t0
        self.stats.router_batches += 1
        return pred, choice, sigma, esc

    def _sanitize_batch(self, toks, pred, choice=None):
        """``REPRO_SANITIZE``: validate one scored batch.  Token ids are
        range-checked host-side (they arrive as numpy); router outputs
        are checked under checkify (see ``kernels.sanitize`` for why the
        checks wrap the jit boundary instead of the kernel)."""
        vocab = self.rc.vocab_size
        if toks.min() < 0 or toks.max() >= vocab:
            raise ValueError(
                f"router_score: token id out of range [0, {vocab})")
        M = self.rc.n_models

        def _checks(p, c):
            sanitize.check_finite("router_score", "predicted losses", p)
            if c is not None:
                sanitize.check_in_range("router_score", "expert choice",
                                        c, 0, M)

        if choice is None:
            sanitize.run_checks(lambda p: _checks(p, None), pred)
        else:
            sanitize.run_checks(_checks, pred, choice)

    def _embed_batch(self, reqs: list[Request]) -> np.ndarray:
        """Pooled router embeddings (B, d) for the semantic cache tier —
        one encoder pass over the batch, bucket-padded like
        ``_score_batch``.  Counts as a router forward in the stats (it
        is most of one)."""
        B = len(reqs)
        toks = np.stack([r.tokens for r in reqs])
        t0 = self._now()
        Bp = self._bucket(B)
        if Bp != B:
            toks = np.concatenate(
                [toks, np.zeros((Bp - B,) + toks.shape[1:], toks.dtype)])
        emb = np.asarray(self._embed(self.router_params,
                                     jnp.asarray(toks)))[:B]
        self.stats.router_time_s += self._now() - t0
        self.stats.router_batches += 1
        return emb

    def _score_from_emb(self, reqs: list[Request], emb: np.ndarray,
                        ) -> tuple[np.ndarray, np.ndarray]:
        """Finish scoring from precomputed pooled embeddings: loss head
        + host-side constrained argmin (the reference-path math — the
        semantic tier reuses the T3 probe's encoder pass instead of
        re-running the fused decision kernel)."""
        B = len(reqs)
        t0 = self._now()
        Bp = self._bucket(B)
        embp = emb
        if Bp != B:
            embp = np.concatenate(
                [emb, np.zeros((Bp - B, emb.shape[1]), emb.dtype)])
        pred = np.asarray(self._head_from_emb(self.router_params,
                                              jnp.asarray(embp)))[:B]
        scores = pred.copy()
        for c in self.constraints:
            lam = np.array([r.lambdas.get(c.name, 0.0) for r in reqs])
            scores = scores + lam[:, None] * c.values[None, :]
        choice = scores.argmin(axis=1)
        self.stats.router_time_s += self._now() - t0
        return pred, choice

    def _sigma_batch(self, reqs: list[Request]) -> np.ndarray:
        """Per-expert predictive uncertainty sigma (B, M) for a batch —
        a second (tiny) router pass, paid only by cascade traffic.

        Deliberately NOT fused with the scoring jit: reusing its
        embedding would change the compiled program and forfeit the
        bit-for-bit single-shot parity with the pre-cascade engine that
        tests/test_cascade.py enforces.  The router is BERT-tiny scale,
        so the duplicate encoder pass is noise next to expert
        execution; revisit only if the router grows."""
        B = len(reqs)
        toks = np.stack([r.tokens for r in reqs])
        Bp = self._bucket(B)
        if Bp != B:
            toks = np.concatenate(
                [toks, np.zeros((Bp - B,) + toks.shape[1:], toks.dtype)])
        return np.asarray(
            self._sigma(self.router_params, jnp.asarray(toks)))[:B]

    def _cascade(self, reqs: list[Request], pred: np.ndarray,
                 choice: np.ndarray) -> tuple[np.ndarray, np.ndarray,
                                              np.ndarray]:
        """Abstention/escalation pass over a scored batch.

        Returns ``(final_choice (B,), depth (B,), confidence (B,))``.
        When no request in the batch asks for a confidence floor the
        sigma pass is skipped and the scores' choice passes through
        untouched — the single-shot fast path.  Escalation is router-
        preferred: each step re-runs the constrained objective over the
        strictly-larger experts (``cascade_choice`` with the request's
        lambda-weighted scores).
        """
        B = len(reqs)
        depth = np.zeros(B, np.int64)
        conf = np.ones(B, np.float64)
        if (self.cascade_max_depth <= 0
                or not any(r.min_confidence > 0.0 for r in reqs)):
            return choice, depth, conf
        confm = confidence_scores(self._sigma_batch(reqs))
        # constrained routing scores L-hat + sum_j lambda_j C_j, (B, M)
        scores = pred + lambda_matrix(reqs, self._cnames) @ self._cmat
        final = np.array(choice, np.int64, copy=True)
        for i, r in enumerate(reqs):
            if r.min_confidence <= 0.0:
                continue
            final[i], depth[i] = cascade_choice(
                int(choice[i]), confm[i], r.min_confidence,
                self._esc_order, self.cascade_max_depth, scores[i])
            conf[i] = confm[i, final[i]]
        return final, depth, conf

    def _cascade_fused(self, reqs: list[Request], pred: np.ndarray,
                       choice: np.ndarray, sigma: np.ndarray,
                       esc: np.ndarray) -> tuple[np.ndarray, np.ndarray,
                                                 np.ndarray]:
        """Epilogue of the one-launch cascade decision: resolve each
        request's per-request threshold against the kernel's confidence
        and depth-1 escalation target.

        Same contract as ``_cascade`` — ``(final, depth, confidence)``
        with confidence computed in float64 from sigma exactly as the
        staged path does.  The depth-1 common case needs no further
        scoring work; the rare request that is *still* under-confident
        after one step (and has ladder left, and ``cascade_max_depth >
        1``) re-runs the staged ``cascade_choice`` walk from scratch,
        so deep escalations match the staged path by construction."""
        B = len(reqs)
        depth = np.zeros(B, np.int64)
        conf = np.ones(B, np.float64)
        final = np.array(choice, np.int64, copy=True)
        confm = confidence_scores(sigma)
        top = len(self._esc_order) - 1
        scores = None
        for i, r in enumerate(reqs):
            thr = r.min_confidence
            if thr <= 0.0:
                continue
            c0 = int(choice[i])
            if confm[i, c0] >= thr or self._ladder_pos[c0] >= top:
                conf[i] = confm[i, c0]
                continue
            e1 = int(esc[i])
            if (confm[i, e1] < thr and self._ladder_pos[e1] < top
                    and self.cascade_max_depth > 1):
                # depth >= 2: staged walk from scratch (exact fallback)
                if scores is None:
                    scores = (pred
                              + lambda_matrix(reqs, self._cnames)
                              @ self._cmat)
                final[i], depth[i] = cascade_choice(
                    c0, confm[i], thr, self._esc_order,
                    self.cascade_max_depth, scores[i])
                conf[i] = confm[i, final[i]]
            else:
                final[i], depth[i], conf[i] = e1, 1, confm[i, e1]
        return final, depth, conf

    def _route_admitted(self, reqs: list[Request]) -> tuple[
            np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray,
            np.ndarray]:
        """Run the admission half of the pipeline (Route -> Cascade ->
        Fallback): cached requests skip scoring, misses are scored as
        one (smaller) batch, cascaded, and memoised post-cascade; the
        health consult then re-routes any row whose chosen expert is
        down or saturated (no-op without a health tracker).

        Returns ``(pred_losses (B, M), choice (B,), cached (B,) bool,
        depth (B,) int, confidence (B,) float, fallback_depth (B,)
        int)`` — ``choice`` is the final post-escalation, post-fallback
        expert.
        """
        ctx = self.pipeline.admit(reqs)
        return (ctx.pred, ctx.choice, ctx.cached, ctx.depth,
                ctx.confidence, ctx.fallback_depth)

    def _route_batch(self, reqs: list[Request]) -> tuple[np.ndarray,
                                                         np.ndarray]:
        """Route one batch of requests (cache-aware); see
        ``_route_admitted`` for the variant that also reports hits,
        cascade depth and confidence."""
        pred, choice, _, _, _, _ = self._route_admitted(reqs)
        return pred, choice

    # ------------------------------------------------ online adaptation

    def _maybe_adapt(self):
        """Feedback-cadenced router refresh (called by the Feedback
        stage after each flush).

        One incremental update per ``adapt_every`` published feedback
        samples — a large flush that publishes several multiples of
        ``adapt_every`` at once applies every update it owes, so the
        adaptation rate tracks the documented cadence regardless of
        micro-batch size.  Each update replays a fresh batch, runs the
        jit'd step on shadow weights, measures the batch prediction
        error before/after, and publishes the new snapshot with an
        atomic version-bumping swap.  The decision cache is cleared on
        swap — the version in the key already makes stale verdicts
        unreachable; clearing just reclaims their memory.
        """
        if self.adapt_every <= 0 or self.replay is None:
            return
        while (self.replay.seen - self._fb_at_last_update
               >= self.adapt_every):
            self._fb_at_last_update += self.adapt_every
            t0 = self._now()
            toks, eidx, obs = self.replay.sample(self.adapt_batch,
                                                 self._adapt_rng)
            jt, je, jo = (jnp.asarray(toks), jnp.asarray(eidx),
                          jnp.asarray(obs))
            new_params, errs = self._adapt_step(self.router_params,
                                                jt, je, jo)
            errs = np.asarray(errs)  # one sync for both error scalars
            self._router = self._router.swap(new_params)
            if self.cache is not None:
                self.cache.clear()
            self._assert_cache_version()
            self.stats.adapt_updates += 1
            self.stats.router_version = self._router.version
            self.stats.adapt_pre_err = float(errs[0])
            self.stats.adapt_post_err = float(errs[1])
            self.stats.adapt_time_s += self._now() - t0

    def _assert_cache_version(self):
        """Sanitizer invariant, checked after every swap: no surviving
        decision-cache entry may carry a router version other than the
        live snapshot's — a stale hit would serve verdicts scored by
        superseded parameters."""
        if self.cache is None:
            return
        stale = self.cache.stale_versions(self._router.version)
        assert not stale, (
            f"decision cache holds entries for router version(s) "
            f"{sorted(stale)} but version {self._router.version} is live")

    # --------------------------------------------------- expert executor

    def _run_expert(self, e, reqs: list[Request]):
        """Execute one padded per-expert micro-batch; returns per-example
        (preds, loss, acc) arrays trimmed back to len(reqs).

        With a placement map (mesh serving), the micro-batch is
        *dispatched*: the least-busy device stream among the expert's
        replica slices runs the whole batch with parameters committed to
        that device (first dispatch per (expert, device) pays the
        transfer, after that the replica is resident).  Committed
        execution keeps the per-flush program identical to the
        single-device engine — the mesh changes *where* a flush runs,
        never *what* it computes."""
        n = len(reqs)
        Bp = self._bucket(n)
        S = len(reqs[0].tokens)
        toks = np.zeros((Bp, S), reqs[0].tokens.dtype)
        targets = np.zeros((Bp, S), np.int32)
        mask = np.zeros((Bp, S), np.int32)
        for j, r in enumerate(reqs):
            toks[j] = r.tokens
            if r.targets is not None:
                targets[j] = r.targets
            if r.mask is not None:
                mask[j] = r.mask
        if self.placement is not None:
            ei = self._expert_idx[e.name]
            slot = self.streams.least_busy(self._expert_streams[ei])
            dev = self._devices[slot]
            key = (ei, slot)
            ep = self._expert_params_on.get(key)
            if ep is None:
                ep = jax.device_put(e.params, dev)
                self._expert_params_on[key] = ep
            t0 = self._now()
            preds, ex_loss, ex_acc = self._expert_fns[e.name](
                ep, jax.device_put(toks, dev),
                jax.device_put(targets, dev), jax.device_put(mask, dev))
            out = (np.asarray(preds)[:n], np.asarray(ex_loss)[:n],
                   np.asarray(ex_acc)[:n])
            # attribute the flush's (blocked) wall time to its stream —
            # the overlapped-makespan signal bench_mesh scales on
            self.streams.record(slot, self._now() - t0, tokens=n * S)
            self.stats.bucket_hits[Bp] += 1
            self.stats.padded_rows += Bp - n
            return out
        preds, ex_loss, ex_acc = self._expert_fns[e.name](
            e.params, jnp.asarray(toks), jnp.asarray(targets),
            jnp.asarray(mask))
        self.stats.bucket_hits[Bp] += 1
        self.stats.padded_rows += Bp - n
        return (np.asarray(preds)[:n], np.asarray(ex_loss)[:n],
                np.asarray(ex_acc)[:n])

    def _execute(self, expert_idx: int, entries: list[LaneEntry],
                 reason: str) -> list[Result]:
        """Run the flush half of the pipeline (Execute -> Feedback) on
        one per-expert micro-batch and return its Results."""
        return self.pipeline.flush(expert_idx, entries, reason)

    def _flush_or_fail(self, sched: ExpertScheduler, expert_idx: int,
                       entries: list[LaneEntry], reason: str,
                       ) -> list[Result]:
        """Execute one scheduled flush, honouring the scheduler's
        armed failure injections and feeding the health tracker.

        A failed flush never loses a request: with a health tracker and
        fallback budget left, its entries are re-routed through the
        fallback chain into other experts' lanes (``Result`` arrives
        later, with a higher ``fallback_depth``); otherwise each entry
        yields a terminal failed ``Result`` (``failed=True``,
        ``flush_reason="failed"``) so the client sees the rejection
        instead of a hang."""
        if sched.take_failure(expert_idx):
            if self.streams is not None:
                # a failed flush occupies no stream time, but the
                # per-device telemetry should still show where it was
                # headed: charge the failure to the home slice's
                # least-busy stream (the dispatch _run_expert would
                # have made)
                self.streams.record_failure(self.streams.least_busy(
                    self._expert_streams[expert_idx]))
            return self._failed_flush(sched, expert_idx, entries)
        t0 = self._now()
        out = self._execute(expert_idx, entries, reason)
        if self.health is not None:
            self.health.observe_flush(expert_idx, self._now() - t0,
                                      ok=True)
        return out

    def _unrecord_result(self, res: Result) -> None:
        """Reverse the per-request ``EngineStats`` accounting of one
        Result whose speculative execution was discarded (the cascade
        verdict escalated after the provisional entry already flushed).

        Only the per-request counters are reverted — flush counts,
        bucket hits, padded rows and expert wall time stay, because the
        compute really happened; ``spec_wasted_tokens`` is the honest
        record of that waste.  Replay feedback from the wasted
        execution also stays: the (prompt, expert, loss) observation is
        real even though the Result is withdrawn."""
        st = self.stats
        if res.failed:
            st.failed -= 1
            return
        st.served -= 1
        st.per_expert[res.expert] -= 1
        if st.per_expert[res.expert] == 0:
            del st.per_expert[res.expert]
        st.total_flops -= res.flops_proxy
        try:
            st.latencies.remove(res.latency_s)
        except ValueError:
            pass
        st.cascade_depth_hist[res.cascade_depth] -= 1
        if st.cascade_depth_hist[res.cascade_depth] == 0:
            del st.cascade_depth_hist[res.cascade_depth]
        try:
            st.tier_latencies[res.cascade_depth].remove(res.latency_s)
        except (KeyError, ValueError):
            pass
        if res.cascade_depth > 0:
            st.escalations -= 1

    def _failed_flush(self, sched: ExpertScheduler, expert_idx: int,
                      entries: list[LaneEntry]) -> list[Result]:
        """One lane flush failed: record it, then re-route or fail each
        entry.  Re-routing re-scores the request's own constrained
        objective with the failed expert masked out (same rule as the
        route-time fallback stage) and re-enqueues it; its
        ``fallback_depth`` stays monotone across the bounces, and a
        request whose depth would exceed ``fallback_max_depth`` plus one
        full sweep of the library fails terminally instead of bouncing
        forever."""
        e = self.library[expert_idx]
        self.stats.expert_failures[e.name] += 1
        if self.health is not None:
            self.health.record_failure(expert_idx)
        budget = self.fallback_max_depth + len(self.library)
        failed: list[Result] = []
        lam = lambda_matrix([en.req for en in entries], self._cnames)
        scores = None
        if self.health is not None and self.fallback_max_depth > 0:
            scores = np.stack([en.pred for en in entries]) + lam @ self._cmat
            healthy = self.health.healthy_mask().copy()
            avail = self.health.available_mask().copy()
            # the expert that just failed is off the table either way
            healthy[expert_idx] = avail[expert_idx] = False
        now = self._now()
        for j, en in enumerate(entries):
            target = None
            if scores is not None and en.fallback_depth < budget:
                final, fdepth, degraded = fallback_choice(
                    scores[j], healthy, avail, expert_idx,
                    self._esc_order, self.fallback_max_depth)
                if final != expert_idx:
                    target = (final, fdepth, degraded)
            if target is None:
                r = en.req
                self.stats.failed += 1
                failed.append(Result(
                    uid=r.uid, expert=e.name, pred_losses=en.pred,
                    predictions=np.zeros(0, np.int64), loss=None,
                    accuracy=None, flops_proxy=0.0,
                    latency_s=(max(now - r.arrival, 0.0)
                               if r.arrival is not None else 0.0),
                    cached=en.cached, flush_reason="failed",
                    cascade_depth=en.depth, confidence=en.confidence,
                    fallback_depth=en.fallback_depth, failed=True))
                continue
            final, fdepth, degraded = target
            self.stats.reroutes += 1
            if degraded:
                self.stats.degraded += 1
            sched.push(final, en.req, en.pred, en.cached, en.depth,
                       en.confidence, en.fallback_depth + fdepth)
        return failed

    # -------------------------------------------------------- disciplines

    def run(self) -> list[Result]:
        """FIFO drain: route the queue in admission-batch slices and
        launch every per-expert group immediately, however ragged.

        Returns one Result per request.  This is the baseline discipline
        ``serve()`` is benchmarked against (``bench_scheduler``).
        """
        results: list[Result] = []
        while self.queue:
            batch, self.queue = (self.queue[:self.max_batch],
                                 self.queue[self.max_batch:])
            (pred, choice, cached, depth, conf,
             fdepth) = self._route_admitted(batch)
            by_expert: dict[int, list[int]] = defaultdict(list)
            for i, c in enumerate(choice):
                by_expert[int(c)].append(i)
            for mi, idxs in sorted(by_expert.items()):
                entries = [LaneEntry(batch[i], pred[i], i, bool(cached[i]),
                                     int(depth[i]), float(conf[i]),
                                     int(fdepth[i]))
                           for i in idxs]
                results.extend(self._execute(mi, entries, "fifo"))
        return results

    def serve(self, request_iter: Iterable[Request | None],
              ) -> Iterator[Result]:
        """Continuous batching: stream requests in, stream Results out.

        ``request_iter`` yields ``Request``s, or ``None`` as an *idle
        tick* (e.g. from an arrival simulator between arrivals) that
        gives the scheduler a chance to fire ``max_wait_s`` deadline
        flushes while no new work is arriving.  Admitted requests are
        scored in batches of up to ``max_batch`` and pushed into
        per-expert lanes; lanes flush on a full bucket or on deadline,
        and everything still pending is drained when the iterator is
        exhausted — shutdown leaves no request behind.  Requests already
        enqueued via ``submit()`` are admitted first.

        On an idle tick a partial admission batch is scored only once
        its oldest request has aged past ``max_wait_s / 2`` — bursts
        keep coalescing into batched router passes instead of
        degenerating to batch-of-1 scoring, while the lane deadline
        (measured from ``Request.arrival``) still bounds total wait.

        With ``speculate=True`` (and a cascade enabled, no health
        tracker) admission is split: every request is laned on its
        *router* choice immediately and the cascade verdict is deferred
        until after the tick's flushes launch.  A verdict that confirms
        the pick promotes the provisional entry in place; one that
        escalates cancels it (or, if it already flushed, discards the
        speculative Result and reverts its accounting) and re-lanes the
        request on the escalation target.  Exactly one Result per
        request either way; ``EngineStats`` counts hits, cancels and
        wasted work.
        """
        sched = ExpertScheduler(len(self.library), self.lane_target,
                                self.max_wait_s)
        if self.placement is not None:
            # each expert lane carries its home device slice so flushes
            # stream into the placement's per-device execution slots
            sched.assign_slots(self.placement)
        self.scheduler = sched
        admitted: list[Request] = []
        # speculation is sound only when the Fallback stage is a strict
        # no-op (no health tracker): deferring Cascade must not reorder
        # it around a health consult
        spec_on = (self.speculate and self.cascade_max_depth > 0
                   and self.health is None)
        # speculative-escalation state: admission contexts whose cascade
        # verdict is still deferred, the uids whose lane entries are
        # provisional, and Results from flushes that executed a
        # provisional entry before its verdict landed
        inflight: list[tuple[RouteContext, list[int]]] = []
        pending: dict = {}    # uid -> speculatively chosen expert
        held: dict = {}       # uid -> Result awaiting its verdict

        def _push_ctx(ctx, specs=frozenset()):
            for i, r in enumerate(ctx.reqs):
                sched.push(int(ctx.choice[i]), r, ctx.pred[i],
                           bool(ctx.cached[i]), int(ctx.depth[i]),
                           float(ctx.confidence[i]),
                           int(ctx.fallback_depth[i]), spec=i in specs)

        def _admit():
            reqs = list(admitted)
            admitted.clear()
            if spec_on:
                # lane everything on the router's first pick now; the
                # sigma/escalation verdict lands via _resolve() after
                # this tick's flushes have launched
                ctx = self.pipeline.route(RouteContext(reqs))
                spec_rows = [i for i in ctx.miss_idx
                             if reqs[i].min_confidence > 0.0]
                if spec_rows:
                    for i in spec_rows:
                        pending[reqs[i].uid] = int(ctx.choice[i])
                        self.stats.spec_launched += 1
                    _push_ctx(ctx, frozenset(spec_rows))
                    inflight.append((ctx, spec_rows))
                else:
                    # no escalation candidates in flight: finish the
                    # admission synchronously, identical to the
                    # non-speculative flow
                    self.pipeline.fallback(self.pipeline.cascade(ctx))
                    _push_ctx(ctx)
            else:
                (pred, choice, cached, depth, conf,
                 fdepth) = self._route_admitted(reqs)
                for i, r in enumerate(reqs):
                    sched.push(int(choice[i]), r, pred[i],
                               bool(cached[i]), int(depth[i]),
                               float(conf[i]), int(fdepth[i]))
            if self.health is not None:
                # saturation signal: every expert's pending depth folds
                # into its health EWMA at each admission (zeros included
                # so idle lanes decay)
                for mi, d in enumerate(sched.depths()):
                    self.health.observe_lane_depth(mi, d)

        def _resolve():
            # land every deferred verdict: finish Cascade -> Fallback
            # on the route-only contexts, then reconcile each
            # provisional lane entry — exactly one Result per request
            while inflight:
                ctx, spec_rows = inflight.pop(0)
                self.pipeline.fallback(self.pipeline.cascade(ctx))
                for i in spec_rows:
                    r = ctx.reqs[i]
                    first = pending.pop(r.uid)
                    final = int(ctx.choice[i])
                    d = int(ctx.depth[i])
                    cf = float(ctx.confidence[i])
                    if d == 0:
                        # hit: the provisional entry (or its already-
                        # flushed Result) becomes authoritative
                        self.stats.spec_hits += 1
                        en = sched.find_entry(first, r.uid)
                        if en is not None:
                            en.spec = False
                            en.confidence = cf
                        else:
                            res = held.pop(r.uid)
                            res.confidence = cf
                            yield res
                        continue
                    en = sched.remove_entry(first, r.uid)
                    if en is not None:
                        # still queued: cancel and re-lane on the
                        # escalation target — no wasted compute
                        self.stats.spec_cancelled += 1
                        sched.push(final, r, en.pred, en.cached, d, cf,
                                   en.fallback_depth)
                    else:
                        # the provisional copy already executed: count
                        # the waste, revert its per-request accounting,
                        # re-lane on the verdict's expert
                        self.stats.spec_wasted += 1
                        self.stats.spec_wasted_tokens += len(r.tokens)
                        self._unrecord_result(held.pop(r.uid))
                        sched.push(final, r, ctx.pred[i],
                                   bool(ctx.cached[i]), d, cf,
                                   int(ctx.fallback_depth[i]))

        if self.queue:
            queued, self.queue = self.queue, []
            request_iter = itertools.chain(queued, request_iter)

        for item in request_iter:
            if item is not None:
                if item.arrival is None:
                    item.arrival = self._now()
                admitted.append(item)
            # full batch admits immediately; a partial batch admits once
            # its oldest request has aged, whether the wake-up was a new
            # request or an idle tick — score it so its requests start
            # aging in their lanes
            if admitted and (len(admitted) >= self.max_batch
                             or (self._now() - admitted[0].arrival
                                 >= 0.5 * self.max_wait_s)):
                _admit()
            for mi, entries, reason in sched.pop_ready(self._now()):
                for res in self._flush_or_fail(sched, mi, entries,
                                               reason):
                    if res.uid in pending:
                        held[res.uid] = res
                    else:
                        yield res
            if inflight:
                yield from _resolve()
        # input exhausted: shutdown drain leaves no request behind
        if admitted:
            _admit()
        if inflight:
            yield from _resolve()
        # a drain flush may re-route entries into other lanes (failure
        # injection during shutdown), so drain until quiescent
        while sched.pending:
            for mi, entries, reason in sched.drain():
                yield from self._flush_or_fail(sched, mi, entries,
                                               reason)
        assert not inflight and not pending and not held, (
            "speculation left unresolved verdicts or held Results")
        for mi, peak in sched.peaks().items():
            name = self.library[mi].name
            self.stats.lane_peaks[name] = max(
                self.stats.lane_peaks.get(name, 0), peak)
        for mi, peak in sched.esc_peaks().items():
            name = self.library[mi].name + "@esc"
            self.stats.lane_peaks[name] = max(
                self.stats.lane_peaks.get(name, 0), peak)
