"""The Tryage serving engine: batched router scoring -> constrained routing
-> per-expert micro-batched execution.

This is the production form of the paper's dispatch loop: requests queue
up, the perceptive router scores a whole batch in one forward pass, the
routing objective (with per-request lambda weights from user flags) picks
an expert per prompt, prompts are grouped into per-expert micro-batches and
executed, and results stream back with measured loss/accuracy plus a FLOPs
proxy for the cost/performance telemetry that the Pareto analysis consumes.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from collections import defaultdict
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.library import ModelLibrary
from repro.core.objective import Constraint
from repro.core.router import RouterConfig, predict_losses
from repro.models.model import forward
from repro.serving.requests import Request, Result


@dataclasses.dataclass
class EngineStats:
    served: int = 0
    per_expert: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    total_flops: float = 0.0
    router_time_s: float = 0.0
    expert_time_s: float = 0.0

    def summary(self) -> dict:
        return {"served": self.served,
                "per_expert": dict(self.per_expert),
                "total_flops": self.total_flops,
                "router_time_s": round(self.router_time_s, 3),
                "expert_time_s": round(self.expert_time_s, 3)}


class TryageEngine:
    def __init__(self, library: ModelLibrary, router_params,
                 rc: RouterConfig, constraints: Sequence[Constraint] = (),
                 max_batch: int = 16, use_kernel: bool = False):
        assert len(library) == rc.n_models
        self.library = library
        self.router_params = router_params
        self.rc = rc
        self.constraints = list(constraints)
        self.max_batch = max_batch
        self.use_kernel = use_kernel
        self.queue: list[Request] = []
        self.stats = EngineStats()

        self._score = jax.jit(
            lambda p, toks: predict_losses(p, rc, {"tokens": toks},
                                           use_kernel=use_kernel))
        self._expert_fns = {}
        for e in library.experts:
            self._expert_fns[e.name] = jax.jit(
                functools.partial(self._expert_forward, cfg=e.cfg))

    @staticmethod
    def _expert_forward(params, toks, *, cfg):
        logits, _, _ = forward(params, cfg, {"tokens": toks}, mode="train",
                               remat=False)
        return jnp.argmax(logits.astype(jnp.float32), axis=-1)

    # ------------------------------------------------------------- api

    def submit(self, req: Request):
        self.queue.append(req)

    def _route_batch(self, reqs: list[Request]) -> np.ndarray:
        toks = np.stack([r.tokens for r in reqs])
        t0 = time.time()
        pred = np.asarray(self._score(self.router_params, jnp.asarray(toks)))
        self.stats.router_time_s += time.time() - t0
        # per-request lambdas: score = L-hat + sum_j lambda_j C_j
        scores = pred.copy()
        for c in self.constraints:
            lam = np.array([r.lambdas.get(c.name, 0.0) for r in reqs])
            scores = scores + lam[:, None] * c.values[None, :]
        return pred, scores.argmin(axis=1)

    def run(self) -> list[Result]:
        """Drain the queue; returns one Result per request."""
        results: list[Result] = []
        while self.queue:
            batch, self.queue = (self.queue[:self.max_batch],
                                 self.queue[self.max_batch:])
            pred, choice = self._route_batch(batch)
            by_expert: dict[int, list[int]] = defaultdict(list)
            for i, c in enumerate(choice):
                by_expert[int(c)].append(i)
            for mi, idxs in sorted(by_expert.items()):
                e = self.library[mi]
                toks = np.stack([batch[i].tokens for i in idxs])
                t0 = time.time()
                preds = np.asarray(
                    self._expert_fns[e.name](e.params, jnp.asarray(toks)))
                dt = time.time() - t0
                self.stats.expert_time_s += dt
                for j, i in enumerate(idxs):
                    r = batch[i]
                    loss = acc = None
                    if r.targets is not None and r.mask is not None:
                        m = r.mask.astype(bool)
                        if m.any():
                            acc = float((preds[j][m] == r.targets[m]).mean())
                    flops = 2.0 * e.n_params * len(r.tokens)
                    results.append(Result(
                        uid=r.uid, expert=e.name, pred_losses=pred[i],
                        predictions=preds[j], loss=loss, accuracy=acc,
                        flops_proxy=flops, latency_s=dt / max(len(idxs), 1)))
                    self.stats.served += 1
                    self.stats.per_expert[e.name] += 1
                    self.stats.total_flops += flops
        return results
