"""Cross-batch expert-affinity scheduler: the gap between the routing
half (Route -> Cascade) and the execution half (Execute -> Feedback) of
the staged serving pipeline.

The routing stage (``TryageEngine._route_batch``) scores admitted
requests and tags each with an expert choice; this module owns what
happens next.  Every expert gets one *lane* of pending routed requests,
and a micro-batch is launched only when

  * the lane reaches its bucket ``target`` (a power of two, so the
    flushed micro-batch is a full bucket with zero padded rows), or
  * the lane's oldest request has waited longer than ``max_wait_s``
    (deadline flush — latency wins over occupancy), or
  * the engine is shutting down (drain flush — nothing is left behind).

Because lanes persist across admission batches, same-expert requests
from *different* admission batches coalesce into full buckets instead of
launching as ragged per-batch tails — the continuous-batching behaviour
the FIFO drain in ``TryageEngine.run`` cannot provide.

When a lane is over-full, ``Request.priority`` decides who ships first:
entries are ordered by (priority descending, admission order ascending),
so high-priority requests ride the next flush and equal-priority
requests stay FIFO.

Cascade escalation lanes: requests the routing stage *escalated* (the
router's confidence in its first pick fell below the request's
``min_confidence`` threshold, see ``core.objective.cascade_choice``) are
re-enqueued into a second, per-expert *escalation lane* targeting the
larger expert instead of riding the regular lane.  Escalation lanes
flush under the same target/deadline/drain rules but keep recovered
traffic separate, so tier-0 micro-batches stay full and per-tier
telemetry (``EngineStats``) stays honest.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.serving.requests import Request

# flush reasons recorded in EngineStats.flushes
FLUSH_TARGET = "target"
FLUSH_DEADLINE = "deadline"
FLUSH_DRAIN = "drain"


@dataclasses.dataclass
class LaneEntry:
    """One routed request waiting in an expert lane."""

    req: Request
    pred: np.ndarray          # router's predicted losses row, (M,) f32
    seq: int                  # global admission order, FIFO tiebreak
    cached: bool = False      # routing decision came from the cache
    depth: int = 0            # cascade escalation steps (0 = first pick)
    confidence: float = 1.0   # router confidence in the final expert
    fallback_depth: int = 0   # health-fallback re-selections so far
    spec: bool = False        # provisional: cascade verdict still pending

    @property
    def sort_key(self) -> tuple:
        return (-self.req.priority, self.seq)


class Lane:
    """Pending routed requests for one expert.

    The lane tracks its oldest arrival incrementally: ``push`` is an
    O(1) min-update and ``take`` recomputes the min only over the
    entries it leaves behind.  ``oldest_wait`` is therefore O(1) —
    it runs for every lane on every scheduler tick, and the old
    full-lane ``min()`` re-scan made each tick O(total pending).
    Lane slots (``slot``) are the mesh hook: the engine's placement map
    pins each expert lane to its home device slice so flushes land in
    that slice's execution stream (None = single-device engine).
    """

    def __init__(self, expert_idx: int, slot: int | None = None):
        self.expert_idx = expert_idx
        self.slot = slot
        self.entries: list[LaneEntry] = []
        self.peak = 0
        self._oldest: float | None = None

    def __len__(self) -> int:
        return len(self.entries)

    def push(self, entry: LaneEntry) -> None:
        self.entries.append(entry)
        self.peak = max(self.peak, len(self.entries))
        a = entry.req.arrival
        if a is not None and (self._oldest is None or a < self._oldest):
            self._oldest = a

    def oldest_wait(self, now: float) -> float:
        if not self.entries or self._oldest is None:
            return 0.0
        return now - self._oldest

    def take(self, n: int | None = None) -> list[LaneEntry]:
        """Remove and return the ``n`` highest-(priority, FIFO) entries;
        ``None`` takes everything."""
        self.entries.sort(key=lambda e: e.sort_key)
        if n is None or n >= len(self.entries):
            out, self.entries = self.entries, []
        else:
            out, self.entries = self.entries[:n], self.entries[n:]
        self._recompute_oldest()
        return out

    def remove(self, uid) -> LaneEntry | None:
        """Remove and return the pending entry for ``uid`` (speculation
        cancel), or None if it already flushed."""
        for j, en in enumerate(self.entries):
            if en.req.uid == uid:
                self.entries.pop(j)
                self._recompute_oldest()
                return en
        return None

    def _recompute_oldest(self) -> None:
        if not self.entries:
            self._oldest = None
            return
        arrivals = [
            e.req.arrival for e in self.entries if e.req.arrival is not None
        ]
        self._oldest = min(arrivals) if arrivals else None


class ExpertScheduler:
    """Lane manager for the expert-executor stage.

    Parameters
    ----------
    n_experts:   library size — one lane per expert index.
    target:      lane occupancy that triggers a full-bucket flush.
                 Power-of-two targets flush with zero padded rows.
    max_wait_s:  deadline for the oldest request in a lane; a lane whose
                 oldest request has waited at least this long flushes on
                 the next tick regardless of occupancy.
    """

    def __init__(self, n_experts: int, target: int, max_wait_s: float):
        assert target >= 1 and max_wait_s >= 0.0
        self.target = target
        self.max_wait_s = max_wait_s
        self.lanes = {i: Lane(i) for i in range(n_experts)}
        # escalation lanes: cascade-recovered traffic, one per expert
        self.esc_lanes = {i: Lane(i) for i in range(n_experts)}
        self._seq = 0
        # per-lane failure injection (tests/benchmarks): outstanding
        # failure count per expert; -1 = fail every flush until cleared
        self._inject_fail: dict[int, int] = {}

    def assign_slots(self, placement) -> None:
        """Pin every expert's lanes (both tiers) to the home device
        slice of a ``serving.placement.PlacementMap``.  Health signals
        stay per *expert* — ``depths()``/``saturation()`` are unchanged
        by slot assignment; the slot only tells the Execute stage which
        device stream a flush of this lane prefers."""
        for i, lane in self.lanes.items():
            lane.slot = placement.home(i)
        for i, lane in self.esc_lanes.items():
            lane.slot = placement.home(i)

    # ------------------------------------------------------- routing in

    def push(
        self,
        expert_idx: int,
        req: Request,
        pred: np.ndarray,
        cached: bool = False,
        depth: int = 0,
        confidence: float = 1.0,
        fallback_depth: int = 0,
        spec: bool = False,
    ) -> None:
        """Enqueue a routed request; escalated requests (``depth > 0``)
        are re-enqueued into the target expert's escalation lane.
        ``spec`` marks the entry provisional — its cascade verdict is
        still in flight and may cancel or confirm it."""
        lanes = self.esc_lanes if depth > 0 else self.lanes
        lanes[expert_idx].push(
            LaneEntry(req, pred, self._seq, cached, depth, confidence,
                      fallback_depth, spec)
        )
        self._seq += 1

    def find_entry(self, expert_idx: int, uid) -> LaneEntry | None:
        """The pending regular-lane entry for ``uid``, or None if it
        already flushed.  Speculative entries always ride regular lanes
        (their provisional depth is 0), so only that tier is searched."""
        for en in self.lanes[expert_idx].entries:
            if en.req.uid == uid:
                return en
        return None

    def remove_entry(self, expert_idx: int, uid) -> LaneEntry | None:
        """Cancel the pending regular-lane entry for ``uid``
        (speculation escalated it elsewhere); None if it already
        flushed."""
        return self.lanes[expert_idx].remove(uid)

    # ------------------------------------------------------ batches out

    def pop_ready(self, now: float) -> Iterator[tuple[int, list[LaneEntry], str]]:
        """Yield ``(expert_idx, entries, reason)`` micro-batches that are
        ready to launch at time ``now``.

        Full lanes flush in exact ``target``-sized buckets (repeatedly,
        if a lane holds several buckets' worth); a deadline flush takes
        the whole lane so no stragglers are left waiting again.
        Escalation lanes follow the same rules after the regular lanes.
        """
        for lane in self._all_lanes():
            while len(lane) >= self.target:
                yield lane.expert_idx, lane.take(self.target), FLUSH_TARGET
            if lane.entries and lane.oldest_wait(now) >= self.max_wait_s:
                yield lane.expert_idx, lane.take(None), FLUSH_DEADLINE

    def drain(self) -> Iterator[tuple[int, list[LaneEntry], str]]:
        """Flush everything still pending — shutdown must leave no
        request behind, in either lane tier.

        Flush labels stay honest at shutdown: a lane holding ``target``
        or more entries ships its full buckets as ``FLUSH_TARGET``
        (they are full buckets — that they flush during drain is an
        accident of timing, not a property of the batch), and only the
        ragged tail is labelled ``FLUSH_DRAIN``.  ``EngineStats.flushes``
        therefore counts exactly the partial micro-batches forced out by
        shutdown, matching docs/METRICS.md."""
        for lane in self._all_lanes():
            while len(lane) >= self.target:
                yield lane.expert_idx, lane.take(self.target), FLUSH_TARGET
            if lane.entries:
                yield lane.expert_idx, lane.take(None), FLUSH_DRAIN

    def _all_lanes(self):
        yield from self.lanes.values()
        yield from self.esc_lanes.values()

    # ------------------------------------------------- failure injection

    def inject_failures(self, expert_idx: int, count: int = -1) -> None:
        """Arm the per-lane failure hook: the next ``count`` flushes of
        this expert's lanes *fail* (``count = -1``: every flush until
        ``clear_failures``).  This is the test/benchmark seam for
        degraded-expert scenarios — the engine consumes one armed
        failure per flush via ``take_failure`` and reacts exactly as it
        would to a real execution error (record it in ``ExpertHealth``,
        re-route the entries through the fallback chain, or fail the
        requests when fallback is off)."""
        self._inject_fail[expert_idx] = count

    def clear_failures(self, expert_idx: int) -> None:
        self._inject_fail.pop(expert_idx, None)

    def take_failure(self, expert_idx: int) -> bool:
        """Consume one armed failure for this expert, if any (called by
        the engine once per flush, before execution)."""
        left = self._inject_fail.get(expert_idx, 0)
        if left == 0:
            return False
        if left > 0:
            left -= 1
            if left == 0:
                self._inject_fail.pop(expert_idx, None)
            else:
                self._inject_fail[expert_idx] = left
        return True

    # -------------------------------------------------------- telemetry

    @property
    def pending(self) -> int:
        return sum(len(lane) for lane in self._all_lanes())

    def occupancy(self) -> dict[int, int]:
        """Current pending depth per expert lane (both tiers pooled)."""
        out = {}
        for lane in self._all_lanes():
            if len(lane):
                out[lane.expert_idx] = out.get(lane.expert_idx, 0) + len(lane)
        return out

    def depths(self) -> list[int]:
        """Current pending depth for *every* expert (both tiers pooled,
        zeros included) — the saturation signal ``ExpertHealth`` folds
        into its per-expert depth EWMA at each admission.  Dense on
        purpose: idle lanes must report 0 so their EWMA decays."""
        out = [0] * len(self.lanes)
        for lane in self._all_lanes():
            out[lane.expert_idx] += len(lane)
        return out

    def saturation(self, expert_idx: int) -> float:
        """Pending depth of one expert's lanes as a multiple of the
        flush target (1.0 = exactly one full bucket waiting)."""
        depth = len(self.lanes[expert_idx]) + len(self.esc_lanes[expert_idx])
        return depth / float(self.target)

    def peaks(self) -> dict[int, int]:
        """Peak pending depth per regular expert lane."""
        return {i: lane.peak for i, lane in self.lanes.items() if lane.peak}

    def esc_peaks(self) -> dict[int, int]:
        """Peak pending depth per escalation lane."""
        return {i: lane.peak for i, lane in self.esc_lanes.items() if lane.peak}
