"""Prometheus-text-format metrics rendered from ``EngineStats``.

One registry (``METRICS``) is the single source of truth for every
exported series: name, type, labels, and which piece of engine state it
reads.  ``render()`` walks the registry against a live
``EngineStats``/``ExpertHealth`` pair and emits the standard text
exposition format (``# HELP`` / ``# TYPE`` / samples), so any Prometheus
scraper — or ``curl`` — can consume it.  ``docs/METRICS.md`` documents
the same registry and ``tests/test_metrics_docs.py`` asserts the two
never drift.

Deliberately import-light: numpy only.  The engine is not imported —
``render`` duck-types its ``stats`` argument, so the module loads in a
docs-only CI job with no JAX present.

Serving: ``start_metrics_server(port, collect)`` runs a background
``ThreadingHTTPServer`` whose ``GET /metrics`` calls ``collect()`` for a
fresh rendering on every scrape (``launch/serve.py --metrics-port``
wires this to the live engine); ``render()``'s output can equally be
written to a file at end of run (``--metrics-out``).
"""

from __future__ import annotations

import dataclasses
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Sequence

import numpy as np

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

# upper bounds (seconds) for the request-latency histogram; chosen to
# straddle max_wait_s deadlines from milliseconds to whole seconds
LATENCY_BUCKETS = (0.001, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                   1.0, 2.5, 5.0, 10.0)


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One exported series: its name, Prometheus type, label names, help
    string, and where in the engine state it comes from (documentation
    only — the read itself lives in ``render``)."""

    name: str
    mtype: str                 # counter | gauge | histogram
    labels: tuple
    help: str
    source: str                # "EngineStats.<field>" / "ExpertHealth.<field>"


METRICS: tuple[MetricSpec, ...] = (
    # ------------------------------------------------ traffic counters
    MetricSpec("tryage_requests_served_total", "counter", (),
               "Requests executed and returned as Results.",
               "EngineStats.served"),
    MetricSpec("tryage_requests_by_expert_total", "counter", ("expert",),
               "Requests served, by executing expert.",
               "EngineStats.per_expert"),
    MetricSpec("tryage_requests_admitted_total", "counter", (),
               "Requests admitted through the front end's bounded queue.",
               "EngineStats.admitted"),
    MetricSpec("tryage_requests_shed_total", "counter", (),
               "Requests load-shed at admission (queue full).",
               "EngineStats.shed"),
    MetricSpec("tryage_requests_shed_by_priority_total", "counter",
               ("priority",),
               "Load-shed requests, by Request.priority.",
               "EngineStats.shed_by_priority"),
    MetricSpec("tryage_requests_failed_total", "counter", (),
               "Requests failed outright: expert flush failed and no "
               "fallback was available.",
               "EngineStats.failed"),
    # ---------------------------------------------- routing & cascade
    MetricSpec("tryage_cache_hits_total", "counter", (),
               "Admission rows answered from the decision cache.",
               "EngineStats.cache_hits"),
    MetricSpec("tryage_cache_misses_total", "counter", (),
               "Admission rows freshly scored by the router.",
               "EngineStats.cache_misses"),
    MetricSpec("tryage_cache_tier_hits_total", "counter", ("tier",),
               "Decision-cache hits, by tier (t1 exact LRU, t2 "
               "persistent KV, t3 semantic).",
               "EngineStats.cache_tier_hits"),
    MetricSpec("tryage_cache_revalidations_total", "counter", (),
               "Semantic-tier candidates found within the distance "
               "bound and revalidated against the live router version.",
               "EngineStats.cache_revalidations"),
    MetricSpec("tryage_cache_revalidation_rejects_total", "counter", (),
               "Semantic-tier candidates rejected at revalidation "
               "(stale router version).",
               "EngineStats.cache_revalidation_rejects"),
    MetricSpec("tryage_cache_key_dropped_lambda_total", "counter", (),
               "Request lambda flags with names unknown to the "
               "engine's constraints, dropped from the cache key.",
               "EngineStats.cache_key_dropped_lambda"),
    MetricSpec("tryage_cascade_escalations_total", "counter", (),
               "Requests escalated at least one cascade step.",
               "EngineStats.escalations"),
    MetricSpec("tryage_cascade_depth_total", "counter", ("depth",),
               "Served requests, by cascade escalation depth.",
               "EngineStats.cascade_depth_hist"),
    # --------------------------------------- speculative escalation
    MetricSpec("tryage_speculation_launched_total", "counter", (),
               "Lane entries enqueued before their escalation verdict "
               "resolved (serve() with speculate=True).",
               "EngineStats.spec_launched"),
    MetricSpec("tryage_speculation_hits_total", "counter", (),
               "Speculative entries whose verdict confirmed the "
               "router's first pick.",
               "EngineStats.spec_hits"),
    MetricSpec("tryage_speculation_cancelled_total", "counter", (),
               "Speculative entries pulled back out of their lane "
               "before flushing (verdict escalated; no wasted compute).",
               "EngineStats.spec_cancelled"),
    MetricSpec("tryage_speculation_wasted_total", "counter", (),
               "Speculative executions discarded because the verdict "
               "escalated after the entry already flushed.",
               "EngineStats.spec_wasted"),
    MetricSpec("tryage_speculation_wasted_tokens_total", "counter", (),
               "Tokens executed by discarded speculative flushes.",
               "EngineStats.spec_wasted_tokens"),
    # ------------------------------------------------ health fallback
    MetricSpec("tryage_fallbacks_total", "counter", (),
               "Route-time fallback re-selections (chosen expert "
               "unavailable).",
               "EngineStats.fallbacks"),
    MetricSpec("tryage_fallbacks_by_depth_total", "counter", ("depth",),
               "Route-time fallbacks, by chain-walk depth.",
               "EngineStats.fallback_depth_hist"),
    MetricSpec("tryage_degraded_total", "counter", (),
               "Fallbacks that ended in graceful-degraded mode "
               "(smallest healthy expert).",
               "EngineStats.degraded"),
    MetricSpec("tryage_reroutes_total", "counter", (),
               "Lane entries re-routed after a failed flush.",
               "EngineStats.reroutes"),
    MetricSpec("tryage_expert_failures_total", "counter", ("expert",),
               "Failed flushes, by expert.",
               "EngineStats.expert_failures"),
    # -------------------------------------------- scheduler & compute
    MetricSpec("tryage_flushes_total", "counter", ("reason",),
               "Micro-batch launches, by flush reason (target = full "
               "bucket, incl. at shutdown; deadline; drain = ragged "
               "shutdown tail only; fifo).",
               "EngineStats.flushes"),
    MetricSpec("tryage_padded_rows_total", "counter", (),
               "Wasted rows executed due to bucket padding.",
               "EngineStats.padded_rows"),
    MetricSpec("tryage_flops_proxy_total", "counter", (),
               "Sum of the 2*params*tokens FLOPs proxy over served "
               "requests.",
               "EngineStats.total_flops"),
    MetricSpec("tryage_router_time_seconds_total", "counter", (),
               "Wall time spent in router forward passes.",
               "EngineStats.router_time_s"),
    MetricSpec("tryage_expert_time_seconds_total", "counter", (),
               "Wall time spent in expert forward passes.",
               "EngineStats.expert_time_s"),
    # ------------------------------------------------ online adaptation
    MetricSpec("tryage_adapt_updates_total", "counter", (),
               "Router adaptation updates applied.",
               "EngineStats.adapt_updates"),
    MetricSpec("tryage_feedback_events_total", "counter", (),
               "Observed (prompt, expert, loss) samples published to "
               "replay.",
               "EngineStats.feedback_events"),
    MetricSpec("tryage_router_version", "gauge", (),
               "Version of the router params currently serving.",
               "EngineStats.router_version"),
    MetricSpec("tryage_replay_occupancy", "gauge", (),
               "Replay buffer occupancy (samples held).",
               "EngineStats.replay_len"),
    # ------------------------------------------------------- front end
    MetricSpec("tryage_sessions", "gauge", (),
               "Concurrent client sessions multiplexed by the front end.",
               "EngineStats.sessions"),
    MetricSpec("tryage_admission_queue_peak", "gauge", (),
               "Peak occupancy of the bounded admission queue.",
               "EngineStats.admission_queue_peak"),
    # ----------------------------------------------------- latency
    MetricSpec("tryage_request_latency_seconds", "histogram", (),
               "True enqueue-to-flush latency over the most recent "
               "latency window.",
               "EngineStats.latencies"),
    # ------------------------------------------------- expert health
    MetricSpec("tryage_expert_healthy", "gauge", ("expert",),
               "1 if the expert passes the health checks (no forced "
               "down, failure EWMA below threshold, out of cooldown).",
               "ExpertHealth.healthy"),
    MetricSpec("tryage_expert_available", "gauge", ("expert",),
               "1 if the expert is healthy and not overloaded.",
               "ExpertHealth.available"),
    MetricSpec("tryage_expert_lane_depth_ewma", "gauge", ("expert",),
               "EWMA of the expert's pending lane depth (saturation "
               "signal).",
               "ExpertHealth.depth_ewma"),
    MetricSpec("tryage_expert_flush_latency_ewma_seconds", "gauge",
               ("expert",),
               "EWMA of the expert's flush execution latency.",
               "ExpertHealth.latency_ewma_s"),
    MetricSpec("tryage_expert_failure_ewma", "gauge", ("expert",),
               "EWMA of the expert's flush failure rate.",
               "ExpertHealth.failure_ewma"),
)


def metric_names() -> list[str]:
    """Every exported series name, registry order — the contract that
    ``docs/METRICS.md`` documents and its parity test checks."""
    return [m.name for m in METRICS]


def _esc(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r"\"")


def _fmt(value: float) -> str:
    f = float(value)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class _Writer:
    def __init__(self):
        self.lines: list[str] = []

    def header(self, m: MetricSpec) -> None:
        self.lines.append(f"# HELP {m.name} {m.help}")
        self.lines.append(f"# TYPE {m.name} {m.mtype}")

    def sample(self, name: str, labels: dict, value: float) -> None:
        lab = ""
        if labels:
            inner = ",".join(f'{k}="{_esc(v)}"' for k, v in labels.items())
            lab = "{" + inner + "}"
        self.lines.append(f"{name}{lab} {_fmt(value)}")


def _spec(name: str) -> MetricSpec:
    for m in METRICS:
        if m.name == name:
            return m
    raise KeyError(name)


def _labelled(w: _Writer, name: str, label: str, mapping: dict) -> None:
    w.header(_spec(name))
    for key in sorted(mapping, key=str):
        w.sample(name, {label: key}, mapping[key])


def _scalar(w: _Writer, name: str, value: float) -> None:
    w.header(_spec(name))
    w.sample(name, {}, value)


def _histogram(w: _Writer, name: str, values: Sequence[float]) -> None:
    w.header(_spec(name))
    vals = np.asarray(list(values), np.float64)
    cum = 0
    for ub in LATENCY_BUCKETS:
        cum = int((vals <= ub).sum()) if vals.size else 0
        w.sample(name + "_bucket", {"le": _fmt(ub)}, cum)
    w.sample(name + "_bucket", {"le": "+Inf"}, int(vals.size))
    w.sample(name + "_sum", {}, float(vals.sum()) if vals.size else 0.0)
    w.sample(name + "_count", {}, int(vals.size))


def render(stats, health=None, expert_names: Sequence[str] | None = None
           ) -> str:
    """Render the full registry against a live ``EngineStats`` (and
    optionally ``ExpertHealth``) as Prometheus text exposition format.

    ``expert_names`` maps health indices to expert names for the
    per-expert health gauges; without it (or without ``health``) those
    series render with no samples, headers only — a scraper sees the
    series exist and empty, not absent."""
    w = _Writer()
    _scalar(w, "tryage_requests_served_total", stats.served)
    _labelled(w, "tryage_requests_by_expert_total", "expert",
              dict(stats.per_expert))
    _scalar(w, "tryage_requests_admitted_total", stats.admitted)
    _scalar(w, "tryage_requests_shed_total", stats.shed)
    _labelled(w, "tryage_requests_shed_by_priority_total", "priority",
              dict(stats.shed_by_priority))
    _scalar(w, "tryage_requests_failed_total", stats.failed)
    _scalar(w, "tryage_cache_hits_total", stats.cache_hits)
    _scalar(w, "tryage_cache_misses_total", stats.cache_misses)
    _labelled(w, "tryage_cache_tier_hits_total", "tier",
              dict(stats.cache_tier_hits))
    _scalar(w, "tryage_cache_revalidations_total",
            stats.cache_revalidations)
    _scalar(w, "tryage_cache_revalidation_rejects_total",
            stats.cache_revalidation_rejects)
    _scalar(w, "tryage_cache_key_dropped_lambda_total",
            stats.cache_key_dropped_lambda)
    _scalar(w, "tryage_cascade_escalations_total", stats.escalations)
    _labelled(w, "tryage_cascade_depth_total", "depth",
              dict(stats.cascade_depth_hist))
    _scalar(w, "tryage_speculation_launched_total", stats.spec_launched)
    _scalar(w, "tryage_speculation_hits_total", stats.spec_hits)
    _scalar(w, "tryage_speculation_cancelled_total",
            stats.spec_cancelled)
    _scalar(w, "tryage_speculation_wasted_total", stats.spec_wasted)
    _scalar(w, "tryage_speculation_wasted_tokens_total",
            stats.spec_wasted_tokens)
    _scalar(w, "tryage_fallbacks_total", stats.fallbacks)
    _labelled(w, "tryage_fallbacks_by_depth_total", "depth",
              dict(stats.fallback_depth_hist))
    _scalar(w, "tryage_degraded_total", stats.degraded)
    _scalar(w, "tryage_reroutes_total", stats.reroutes)
    _labelled(w, "tryage_expert_failures_total", "expert",
              dict(stats.expert_failures))
    _labelled(w, "tryage_flushes_total", "reason", dict(stats.flushes))
    _scalar(w, "tryage_padded_rows_total", stats.padded_rows)
    _scalar(w, "tryage_flops_proxy_total", stats.total_flops)
    _scalar(w, "tryage_router_time_seconds_total", stats.router_time_s)
    _scalar(w, "tryage_expert_time_seconds_total", stats.expert_time_s)
    _scalar(w, "tryage_adapt_updates_total", stats.adapt_updates)
    _scalar(w, "tryage_feedback_events_total", stats.feedback_events)
    _scalar(w, "tryage_router_version", stats.router_version)
    _scalar(w, "tryage_replay_occupancy", stats.replay_len)
    _scalar(w, "tryage_sessions", stats.sessions)
    _scalar(w, "tryage_admission_queue_peak", stats.admission_queue_peak)
    _histogram(w, "tryage_request_latency_seconds", stats.latencies)
    health_series = (
        ("tryage_expert_healthy",
         lambda i: 1.0 if health.healthy(i) else 0.0),
        ("tryage_expert_available",
         lambda i: 1.0 if health.available(i) else 0.0),
        ("tryage_expert_lane_depth_ewma",
         lambda i: health.states[i].depth_ewma),
        ("tryage_expert_flush_latency_ewma_seconds",
         lambda i: health.states[i].latency_ewma_s),
        ("tryage_expert_failure_ewma",
         lambda i: health.states[i].failure_ewma),
    )
    for name, read in health_series:
        w.header(_spec(name))
        if health is not None and expert_names is not None:
            for i, ename in enumerate(expert_names):
                w.sample(name, {"expert": ename}, read(i))
    return "\n".join(w.lines) + "\n"


class MetricsServer:
    """Background HTTP server exposing ``GET /metrics``.

    ``collect`` is called on every scrape and must return the rendered
    exposition text — bind it to a live engine with
    ``lambda: render(engine.stats, engine.health, names)``."""

    def __init__(self, port: int, collect: Callable[[], str],
                 host: str = "127.0.0.1"):
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):                          # noqa: N802
                if self.path.rstrip("/") not in ("", "/metrics"):
                    self.send_error(404)
                    return
                body = outer.collect().encode("utf-8")
                self.send_response(200)
                self.send_header("Content-Type", CONTENT_TYPE)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):                 # silence stderr
                pass

        self.collect = collect
        self.httpd = ThreadingHTTPServer((host, port), Handler)
        self.port = self.httpd.server_address[1]
        self._thread = threading.Thread(target=self.httpd.serve_forever,
                                        daemon=True)

    def start(self) -> "MetricsServer":
        self._thread.start()
        return self

    def stop(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()


def start_metrics_server(port: int, collect: Callable[[], str],
                         host: str = "127.0.0.1") -> MetricsServer:
    """Start a daemon-thread metrics endpoint; returns the server (use
    ``.port`` when ``port=0`` picked an ephemeral one)."""
    return MetricsServer(port, collect, host).start()
