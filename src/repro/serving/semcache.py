"""Semantic (approximate) decision-cache tier keyed on router embeddings.

Embedding-space performance prediction implies near-identical prompts
get near-identical verdicts: a paraphrase or lightly-edited retry lands
next to its original in the router's pooled embedding space even though
its token bytes differ, so the exact tiers miss it.  T3 answers such a
miss with the verdict of the *nearest* cached embedding, but only when
it is provably close (squared L2 within a calibrated ``eps``) and only
after revalidation — the stored entry must carry the **live** router
version, and the request's lambda vector and cascade threshold must
match the entry's context exactly (they are part of the context key,
never approximated).  Anything else falls through to a fresh score, so
the PR-4 invariant (stale params can never serve a verdict) holds for
the approximate tier by construction.

``ExactNNIndex`` is the compact ANN structure underneath: an IVF-flat
layout (coarse cells around sampled centroids, per-cell radius) whose
query prunes cells with the triangle inequality — a cell is skipped
only when ``dist(q, centroid) - radius`` already exceeds the best
candidate, so the answer is *exactly* the brute-force nearest
neighbour (tests/test_cache_stack.py holds it to a NumPy ``argmin``
oracle).  Ids are stable: tombstoned slots are reused in place, and
vectors added since the last rebuild sit in a flat pending list that is
always scanned, so pruning stays exact between rebuilds.
"""

from __future__ import annotations

import numpy as np


class ExactNNIndex:
    """Exact nearest-neighbour index over float32 vectors.

    ``add`` returns a stable integer slot id; ``query`` returns
    ``(id, squared_distance)`` for an exact nearest live vector (ties
    broken arbitrarily among equals) or ``None`` when empty;
    ``discard`` tombstones an id.  Tombstoned slots are reused by later
    ``add``s, so the footprint is bounded by the peak live count."""

    def __init__(self, dim: int, min_build: int = 64):
        self.dim = int(dim)
        self._data = np.zeros((0, self.dim), np.float32)
        self._dead = np.zeros(0, bool)
        self._free: list[int] = []           # tombstoned slots to reuse
        self._min_build = min_build
        # coarse layer: centroids (K, d), member ids and radius per cell.
        # Cell membership may go stale (discard + slot reuse); stale
        # members are extra work, never wrong answers — a reused slot is
        # also in the pending list, which every query scans.
        self._centroids: np.ndarray | None = None
        self._cells: list[np.ndarray] = []
        self._radii: np.ndarray | None = None
        self._pending: list[int] = []        # ids not yet covered by cells

    def __len__(self) -> int:
        return int((~self._dead).sum())

    def add(self, vec: np.ndarray) -> int:
        v = np.asarray(vec, np.float32).reshape(self.dim)
        if self._free:
            idx = self._free.pop()
            self._data[idx] = v
            self._dead[idx] = False
        else:
            self._data = np.concatenate([self._data, v[None]])
            self._dead = np.concatenate([self._dead, [False]])
            idx = len(self._data) - 1
        self._pending.append(idx)
        built = len(self) - len(self._pending)
        if len(self._pending) >= max(self._min_build, built):
            self._rebuild()
        return idx

    def discard(self, idx: int) -> None:
        if not self._dead[idx]:
            self._dead[idx] = True
            self._free.append(int(idx))

    def _rebuild(self) -> None:
        """Re-cover every live id with ~sqrt(n) cells around
        evenly-spaced sample centroids (deterministic — no RNG, so the
        index is a pure function of the add/discard sequence)."""
        live = np.flatnonzero(~self._dead)
        self._pending = []
        n = len(live)
        if n == 0:
            self._centroids, self._cells, self._radii = None, [], None
            return
        k = max(1, int(np.sqrt(n)))
        self._centroids = self._data[live[:: max(1, n // k)][:k]].copy()
        d2 = (((self._data[live][:, None, :]
                - self._centroids[None, :, :]) ** 2).sum(-1))
        assign = d2.argmin(1)
        self._cells = [live[assign == c]
                       for c in range(len(self._centroids))]
        self._radii = np.array(
            [np.sqrt(d2[assign == c, c].max()) if (assign == c).any()
             else 0.0 for c in range(len(self._centroids))])

    def query(self, vec: np.ndarray) -> tuple[int, float] | None:
        q = np.asarray(vec, np.float32).reshape(self.dim)
        best_id, best_d2 = -1, np.inf

        def scan(ids: np.ndarray) -> None:
            nonlocal best_id, best_d2
            ids = np.asarray(ids, int)
            ids = ids[~self._dead[ids]]
            if not len(ids):
                return
            d2 = ((self._data[ids] - q) ** 2).sum(1)
            j = int(d2.argmin())
            if d2[j] < best_d2:
                best_id, best_d2 = int(ids[j]), float(d2[j])

        # flat pending tail first (recent inserts are the likeliest hits)
        if self._pending:
            scan(np.array(self._pending))
        if self._centroids is not None:
            dc = np.sqrt(((self._centroids - q) ** 2).sum(1))
            lb = np.maximum(0.0, dc - self._radii)
            for c in np.argsort(lb, kind="stable"):
                # cells sorted by lower bound: the first unbeatable one
                # proves every later cell is unbeatable too (exactness)
                if lb[c] ** 2 >= best_d2:
                    break
                scan(self._cells[c])
        return None if best_id < 0 else (best_id, best_d2)


class _Entry:
    __slots__ = ("version", "pred", "choice", "depth", "confidence")

    def __init__(self, version, pred, choice, depth, confidence):
        self.version = int(version)
        stored = np.array(pred, np.float32)
        stored.setflags(write=False)
        self.pred = stored
        self.choice = int(choice)
        self.depth = int(depth)
        self.confidence = float(confidence)


class SemanticCache:
    """T3: verdicts keyed on (context, router embedding), answered by
    exact-NN within ``eps`` and revalidated against the live router
    version.

    The *context* — the request's lambda vector laid out in constraint
    order plus its cascade threshold — is matched exactly (one index
    per context): only the prompt itself is approximate, never the
    knobs that change what the right verdict is.  ``get`` returns
    ``(entry, status)`` with status ``"hit"`` (served), ``"stale"``
    (nearest neighbour was within the bound but carried a superseded
    router version — rejected and tombstoned) or ``"miss"``.
    Capacity-bounded with FIFO eviction across contexts.
    """

    def __init__(self, eps: float, capacity: int = 65536):
        assert eps > 0.0 and capacity >= 1
        self.eps = float(eps)
        self.capacity = int(capacity)
        self._ctx: dict[tuple, tuple[ExactNNIndex, dict[int, _Entry]]] = {}
        self._size = 0
        self._fifo: list[tuple[tuple, int]] = []   # insert order

    def __len__(self) -> int:
        return self._size

    def put(self, emb: np.ndarray, context: tuple, version: int,
            pred: np.ndarray, choice: int, depth: int = 0,
            confidence: float = 1.0) -> None:
        emb = np.asarray(emb, np.float32).ravel()
        index, entries = self._ctx.setdefault(
            context, (ExactNNIndex(emb.shape[0]), {}))
        idx = index.add(emb)
        entries[idx] = _Entry(version, pred, choice, depth, confidence)
        self._fifo.append((context, idx))
        self._size += 1
        while self._size > self.capacity and self._fifo:
            octx, oidx = self._fifo.pop(0)
            oindex, oentries = self._ctx[octx]
            if oentries.pop(oidx, None) is not None:
                oindex.discard(oidx)
                self._size -= 1

    def get(self, emb: np.ndarray, context: tuple, live_version: int,
            ) -> tuple[tuple | None, str]:
        found = self._ctx.get(context)
        if found is None:
            return None, "miss"
        index, entries = found
        near = index.query(np.asarray(emb, np.float32).ravel())
        if near is None or near[1] > self.eps ** 2:
            return None, "miss"
        e = entries[near[0]]
        if e.version != int(live_version):
            # revalidation failed: the verdict was scored by superseded
            # parameters.  Versions only move forward, so the entry can
            # never serve again — tombstone it on the way out.
            entries.pop(near[0])
            index.discard(near[0])
            self._size -= 1
            return None, "stale"
        return (e.pred, e.choice, e.depth, e.confidence), "hit"

    def stale_versions(self, live_version: int) -> set[int]:
        """Router versions carried by live entries, minus the live one
        (same contract as ``DecisionCache.stale_versions``)."""
        versions = {e.version
                    for _, entries in self._ctx.values()
                    for e in entries.values()}
        return versions - {int(live_version)}

    def clear(self) -> None:
        self._ctx.clear()
        self._fifo.clear()
        self._size = 0


def calibrate_eps(embeddings: np.ndarray, verdicts: np.ndarray,
                  margin: float = 0.5) -> float:
    """Distance bound under which nearest-neighbour verdict reuse is
    safe *on the calibration sample*: ``margin`` times the smallest
    distance between any two embeddings whose verdicts differ.  Any two
    prompts closer than the returned eps agreed on their verdict in the
    sample, with a 1/margin safety factor for unseen traffic.  Returns
    ``inf`` when every calibration verdict agrees (no separating pair —
    pick an application bound instead)."""
    emb = np.asarray(embeddings, np.float64)
    v = np.asarray(verdicts).ravel()
    assert len(emb) == len(v)
    best = np.inf
    for i in range(len(emb) - 1):
        diff = v[i + 1:] != v[i]
        if diff.any():
            d = np.sqrt(((emb[i + 1:][diff] - emb[i]) ** 2).sum(1)).min()
            best = min(best, float(d))
    return margin * best
