"""The serving front end: concurrent client sessions multiplexed into
``TryageEngine.serve()`` through a bounded admission queue.

Until now traffic entered the engine as one in-process iterator — fine
for benchmarks, nothing like an ingress.  This module grows the real
thing in the same single-threaded, generator-driven idiom the engine
already uses:

* A ``Session`` is one client's request stream: any iterable yielding
  ``Request`` objects or ``None`` idle ticks (an arrival simulator whose
  next request is not due yet yields ``None``).  Sessions are polled
  round-robin, one item per session per engine pull, so no client can
  starve the others by producing faster.
* Arrivals land in a bounded **admission queue** (``capacity``).  When
  the queue is full the frontend load-sheds by ``Request.priority``:
  the lowest-priority request — queued or incoming, ties shed the
  newest — is rejected outright and counted in
  ``EngineStats.shed`` / ``shed_by_priority``.  Everything admitted is
  FIFO from there; shedding is the only reordering the queue does.
* The engine consumes the queue through ``ServingFrontend.serve()``,
  which is a drop-in replacement for ``engine.serve(iterator)`` —
  Results stream back exactly as before, and idle ticks propagate so
  the scheduler's deadline flushes keep firing while every session is
  quiet.

Backpressure story: the queue bounds how much admitted-but-unrouted
work can exist, so a burst beyond ``capacity`` costs the *lowest-value*
traffic its admission instead of growing latency without bound for
everyone.  Shed requests never reach the router — they produce no
``Result`` and are listed in ``ServingFrontend.shed_uids`` for the
caller (a real ingress would turn that into an HTTP 429/503).

The frontend is deliberately health-agnostic: overload *inside* the
engine (a saturated expert lane) is the health tracker's job
(``serving.health``), routed around by the fallback stage; overload
*at the door* is the admission queue's job.  The two compose but do not
depend on each other.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Iterable, Iterator

from repro.serving.requests import Request, Result

if TYPE_CHECKING:                                      # pragma: no cover
    from repro.serving.engine import TryageEngine


@dataclasses.dataclass
class Session:
    """One client's request stream.

    ``requests`` yields ``Request`` objects (ready to admit now) or
    ``None`` (the session is alive but has nothing due yet — e.g. a
    timed arrival process waiting for its next arrival).  The session
    ends when the iterable is exhausted.
    """

    name: str
    requests: Iterable[Request | None]


class AdmissionQueue:
    """Bounded FIFO with priority-based load-shedding.

    ``offer`` admits a request if there is room; at capacity it sheds
    the lowest-priority request in play — the incoming one if its
    priority is less than or equal to the current minimum (newest sheds
    first on ties), otherwise the oldest queued request at that minimum
    priority (which frees the slot for the higher-priority arrival).
    Returns the shed ``Request`` (``None`` when nothing was shed), so
    the caller owns the rejection accounting.
    """

    def __init__(self, capacity: int):
        assert capacity >= 1
        self.capacity = capacity
        self._items: list[Request] = []
        self.peak = 0

    def __len__(self) -> int:
        return len(self._items)

    def offer(self, req: Request) -> Request | None:
        if len(self._items) < self.capacity:
            self._items.append(req)
            self.peak = max(self.peak, len(self._items))
            return None
        lowest = min(range(len(self._items)),
                     key=lambda i: self._items[i].priority)
        if req.priority <= self._items[lowest].priority:
            return req                       # incoming is the loser
        shed = self._items.pop(lowest)
        self._items.append(req)
        return shed

    def pop(self) -> Request | None:
        return self._items.pop(0) if self._items else None


class ServingFrontend:
    """Multiplex concurrent sessions into one engine.

    Parameters
    ----------
    engine:    the ``TryageEngine`` to feed (its stats pick up the
               frontend counters: sessions, admitted, shed,
               shed_by_priority, admission queue peak).
    sessions:  the client sessions to serve, polled round-robin.
    capacity:  admission-queue bound; arrivals beyond it shed the
               lowest-priority request in play.
    """

    def __init__(self, engine: TryageEngine, sessions: list[Session],
                 capacity: int = 256):
        assert sessions, "frontend needs at least one session"
        self.engine = engine
        self.sessions = sessions
        self.queue = AdmissionQueue(capacity)
        self.shed_uids: list[int] = []
        engine.stats.sessions = len(sessions)

    def _shed(self, req: Request) -> None:
        st = self.engine.stats
        st.shed += 1
        st.shed_by_priority[int(req.priority)] += 1
        self.shed_uids.append(req.uid)

    def _multiplex(self) -> Iterator[Request | None]:
        """Round-robin the sessions into the admission queue and yield
        admitted requests (or idle ticks) to the engine.

        Each engine pull drives one polling sweep: every live session
        contributes at most one item, due arrivals pass through the
        bounded queue (shedding at capacity), and the oldest admitted
        request is yielded.  With nothing admitted this sweep, a
        ``None`` idle tick is yielded instead so the engine's deadline
        flushes fire while all sessions are quiet."""
        st = self.engine.stats
        live = [iter(s.requests) for s in self.sessions]
        while live or len(self.queue):
            for it in list(live):
                try:
                    item = next(it)
                except StopIteration:
                    live.remove(it)
                    continue
                if item is None:
                    continue
                if item.arrival is None:
                    item.arrival = self.engine._now()
                shed = self.queue.offer(item)
                if shed is not None:
                    self._shed(shed)
            st.admission_queue_peak = max(st.admission_queue_peak,
                                          self.queue.peak)
            nxt = self.queue.pop()
            if nxt is not None:
                st.admitted += 1
                yield nxt
            elif live:
                yield None

    def serve(self) -> Iterator[Result]:
        """Stream Results for everything admitted, until every session
        is exhausted and the engine has drained.  Drop-in for
        ``engine.serve(iterator)`` — shed requests simply never appear
        in the output (their uids are in ``shed_uids``)."""
        return self.engine.serve(self._multiplex())
