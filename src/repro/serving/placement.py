"""Expert -> mesh-slice placement for the sharded Execute stage.

The serving mesh is logically ``(data, model)``: the ``data`` axis
shards the routing stage's admission batches, and the ``model`` axis is
carved into *slices* — one column of devices per slice — that the
Execute stage spreads the expert library over.  A lane flush then runs
on a device owned by its expert's slice instead of serializing every
expert onto device 0, so micro-batches for different experts overlap
in per-device streams.

Two placement rules, both host-side and deterministic:

* **Greedy size-balanced assignment** (LPT): experts are sorted by
  *load* — parameter count times an optional expected traffic share —
  and each is assigned to the currently least-loaded slice.  With
  uniform traffic this balances resident bytes; with a traffic prior
  (benchmarks pre-scan their workload) it balances expected compute.
* **Hot-expert replication**: the ``replicate_hot`` highest-load
  experts are additionally replicated onto *every* slice.  Replicas
  only make sense for experts whose traffic dominates (the flush
  dispatcher picks the least-busy replica stream at flush time), and
  the smallest/hottest experts are exactly the ones a Tryage router
  concentrates traffic on, so replicating them is cheap in bytes and
  large in tail throughput.

Everything here is NumPy/stdlib — no JAX import — so the scheduler,
tests and docs tooling can reason about placement without touching
device state.  The engine owns the actual ``jax.Device`` handles; this
module only speaks slice indices.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class PlacementMap:
    """Immutable expert -> slice assignment.

    ``slices[i]`` is the tuple of slice indices expert ``i`` may execute
    on (its *home* slice first, replicas after).  ``n_slices`` is the
    mesh's ``model``-axis extent.
    """

    n_slices: int
    slices: tuple[tuple[int, ...], ...]

    def __post_init__(self):
        assert self.n_slices >= 1
        for s in self.slices:
            assert s, "every expert needs at least one slice"
            assert all(0 <= k < self.n_slices for k in s)
            assert len(set(s)) == len(s), "duplicate replica slice"

    @property
    def n_experts(self) -> int:
        return len(self.slices)

    def home(self, expert_idx: int) -> int:
        """The expert's primary slice (LPT assignment)."""
        return self.slices[expert_idx][0]

    def slices_for(self, expert_idx: int) -> tuple[int, ...]:
        """All slices holding a replica of this expert."""
        return self.slices[expert_idx]

    def replicated(self, expert_idx: int) -> bool:
        return len(self.slices[expert_idx]) > 1

    def summary(self, names: Sequence[str] | None = None) -> dict:
        """Telemetry view: per-slice expert lists plus the replica set
        (consumed by ``launch.serve`` output and ``bench_mesh``)."""
        label = (names if names is not None
                 else [str(i) for i in range(self.n_experts)])
        per_slice: list[list[str]] = [[] for _ in range(self.n_slices)]
        for i, ss in enumerate(self.slices):
            for k in ss:
                per_slice[k].append(label[i])
        return {
            "n_slices": self.n_slices,
            "per_slice": {k: members for k, members in
                          enumerate(per_slice)},
            "replicated": [label[i] for i in range(self.n_experts)
                           if self.replicated(i)],
        }


def plan_placement(sizes: Sequence[float], n_slices: int,
                   replicate_hot: int = 0,
                   traffic: Sequence[float] | None = None) -> PlacementMap:
    """Greedy size-balanced (LPT) expert -> slice assignment.

    Parameters
    ----------
    sizes:         per-expert cost proxy (parameter count); must be
                   positive.
    n_slices:      number of mesh slices (``model``-axis extent).
    replicate_hot: replicate the top-K experts by load onto every
                   slice (0 disables replication).
    traffic:       optional expected traffic share per expert; load is
                   ``sizes[i] * traffic[i]`` when given, ``sizes[i]``
                   otherwise.

    The assignment is deterministic: ties in load break on expert index,
    ties in slice occupancy break on slice index, so a given library
    always lands the same way and parity tests can pin expectations.
    """
    n = len(sizes)
    assert n >= 1 and n_slices >= 1
    assert all(s > 0 for s in sizes), "expert sizes must be positive"
    if traffic is not None:
        assert len(traffic) == n
        assert all(t >= 0 for t in traffic)
        load = [float(sizes[i]) * (float(traffic[i]) or 1e-9)
                for i in range(n)]
    else:
        load = [float(s) for s in sizes]
    # LPT: heaviest expert first onto the least-loaded slice
    order = sorted(range(n), key=lambda i: (-load[i], i))
    slice_load = [0.0] * n_slices
    homes = [0] * n
    for i in order:
        k = min(range(n_slices), key=lambda s: (slice_load[s], s))
        homes[i] = k
        slice_load[k] += load[i]
    hot = set(sorted(range(n), key=lambda i: (-load[i], i))
              [:max(0, replicate_hot)]) if n_slices > 1 else set()
    slices = []
    for i in range(n):
        if i in hot:
            rest = [k for k in range(n_slices) if k != homes[i]]
            slices.append((homes[i], *rest))
        else:
            slices.append((homes[i],))
    return PlacementMap(n_slices, tuple(slices))


class StreamClock:
    """Busy-time bookkeeping for per-device execution streams.

    One physical host serializes every flush in wall time, but flushes
    dispatched to *different* devices are independent programs a real
    multi-device runtime overlaps.  The engine therefore attributes each
    flush's measured wall time to its device's stream; the *simulated*
    makespan of a run is the busiest stream's total, which is what
    ``bench_mesh`` reports as overlapped throughput.  (On real TPU/GPU
    meshes the dispatch is genuinely asynchronous and the same
    accounting reads actual overlap.)
    """

    def __init__(self, n_streams: int):
        assert n_streams >= 1
        self.n_streams = n_streams
        self.busy_s = [0.0] * n_streams
        self.flushes = [0] * n_streams
        self.tokens = [0] * n_streams
        self.failures = [0] * n_streams

    def least_busy(self, candidates: Sequence[int]) -> int:
        """The least-loaded stream among ``candidates`` (tie -> lowest
        index) — the replica dispatch rule."""
        return min(candidates, key=lambda d: (self.busy_s[d], d))

    def record(self, stream: int, wall_s: float, tokens: int) -> None:
        self.busy_s[stream] += max(float(wall_s), 0.0)
        self.flushes[stream] += 1
        self.tokens[stream] += int(tokens)

    def reset(self) -> None:
        """Zero all counters (benchmarks reset after their warm pass so
        compile time never counts as stream busy time)."""
        self.busy_s = [0.0] * self.n_streams
        self.flushes = [0] * self.n_streams
        self.tokens = [0] * self.n_streams
        self.failures = [0] * self.n_streams

    def record_failure(self, stream: int) -> None:
        """A flush failed before executing: no busy time, but the
        per-device view should show which stream lost the work."""
        self.failures[stream] += 1

    @property
    def makespan_s(self) -> float:
        """Simulated overlapped wall time: the busiest stream."""
        return max(self.busy_s)

    @property
    def total_busy_s(self) -> float:
        """Serialized wall time: every stream's busy time summed."""
        return sum(self.busy_s)

    def summary(self) -> dict:
        return {
            "streams": self.n_streams,
            "busy_s": [round(b, 6) for b in self.busy_s],
            "flushes": list(self.flushes),
            "tokens": list(self.tokens),
            "failures": list(self.failures),
            "makespan_s": round(self.makespan_s, 6),
            "total_busy_s": round(self.total_busy_s, 6),
        }
