"""Request/Result types and the user-flag mini-language.

The paper folds user constraints into the prompt itself, e.g.
"The capital of California is [blank] [Flag: Smallest model]".  We parse
the same flag surface into constraint weights (lambdas).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

_FLAG_RE = re.compile(r"\[flag:\s*([^\]]+)\]", re.IGNORECASE)

# flag phrase -> (constraint name, lambda)
FLAG_TABLE = {
    "smallest model": ("size", 8.0),
    "small model": ("size", 2.0),
    "prefer small": ("size", 1.0),
    "newest model": ("recency", 4.0),
    "recent model": ("recency", 1.0),
    "best model": (None, 0.0),
}


def parse_flags(text: str) -> dict:
    """Extract constraint weights from [Flag: ...] markers."""
    lambdas: dict[str, float] = {}
    for m in _FLAG_RE.finditer(text):
        phrase = m.group(1).strip().lower()
        entry = FLAG_TABLE.get(phrase)
        if entry and entry[0]:
            lambdas[entry[0]] = max(lambdas.get(entry[0], 0.0), entry[1])
    return lambdas


def lambda_matrix(requests: "list[Request]",
                  constraint_names: list) -> np.ndarray:
    """Per-request constraint weights as the (B, n_c) matrix consumed by
    the fused router kernel; column order follows ``constraint_names``.
    With no constraints, returns (B, 1) zeros to pair with the zero-row
    matrix from ``objective.constraint_matrix``.
    """
    if not constraint_names:
        return np.zeros((len(requests), 1), np.float32)
    lam = np.zeros((len(requests), len(constraint_names)), np.float32)
    for i, r in enumerate(requests):
        for j, name in enumerate(constraint_names):
            lam[i, j] = r.lambdas.get(name, 0.0)
    return lam


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray                 # (S,) masked MLM prompt
    targets: Optional[np.ndarray] = None
    mask: Optional[np.ndarray] = None
    lambdas: dict = dataclasses.field(default_factory=dict)
    arrival: Optional[float] = None    # enqueue time (engine clock); the
    #                                    engine stamps it on admission if unset
    priority: int = 0                  # higher flushes first from a full lane
    min_confidence: float = 0.0        # cascade threshold: escalate while the
    #                                    chosen expert's confidence is below
    #                                    this (0 = single-shot, no cascade)


@dataclasses.dataclass
class Result:
    uid: int
    expert: str
    pred_losses: np.ndarray            # router's L-hat over the library
    predictions: np.ndarray            # argmax token at each position
    loss: float | None                 # measured, if targets supplied
    accuracy: float | None
    flops_proxy: float                 # 2 * params * tokens
    latency_s: float                   # true enqueue -> flush latency
    cached: bool = False               # routing decision came from the cache
    flush_reason: str = ""             # target | deadline | drain | fifo
    #                                    (| failed: expert flush failed and
    #                                    fallback could not re-route)
    cascade_depth: int = 0             # escalation steps taken (0 = first pick)
    confidence: float = 1.0            # router confidence in the final expert
    fallback_depth: int = 0            # health-fallback re-selections taken
    #                                    (0 = objective's pick served; monotone
    #                                    over the request's lifetime, route-time
    #                                    fallback + failed-flush re-routes)
    failed: bool = False               # expert execution failed and the request
    #                                    was not served (no fallback available)
