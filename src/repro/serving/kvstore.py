"""Valkey/Redis-shaped KV interface with a crash-safe disk default.

The decision-cache stack's persistent tier (T2) talks to a deliberately
tiny key/value surface — ``get``/``set``/``delete``/``keys``/``flush``/
``close`` over ``bytes`` keys and values — so swapping the disk-backed
default for a real Valkey/Redis client is a one-class adapter, and
tests can substitute ``MemoryKVStore`` for hermetic runs (two engine
replicas sharing one ``MemoryKVStore`` share verdicts the same way two
processes share a Valkey instance).

``DiskKVStore`` is the restart-safe default: an append-only segment log
of crc32-checked records.  Every ``set``/``delete`` appends one framed
record; an in-memory index maps live keys to their latest value, so
reads never touch disk.  Recovery replays the log from byte 0 and stops
at the first torn or corrupt record: the intact prefix is the store, the
tail is *quarantined* to a sidecar file (never served, never fatal) and
the log is truncated back to the last good boundary — killing the
process at any byte offset loses at most the record being written.
Compaction rewrites the live index into a fresh log and publishes it
with an atomic ``os.replace`` (readers of the old path see either the
old complete log or the new complete log, nothing in between).

Fault injection for the crash-safety tests: set ``fail_after_bytes`` and
the next append writes exactly that many bytes of the record before
raising ``SimulatedCrash`` — the torn-tail shape a real ``kill -9``
leaves behind.
"""

from __future__ import annotations

import os
import struct
import zlib

# record framing: MAGIC | op | key-len | value-len | crc32(op+lens+key+value)
_MAGIC = 0xA7
_OP_SET = 0
_OP_DEL = 1
_HEADER = struct.Struct("<BBIII")


class SimulatedCrash(RuntimeError):
    """Raised by the fault-injection hook mid-append (test-only)."""


class KVStore:
    """The Valkey-shaped contract T2 is written against (duck-typed;
    subclassing is optional)."""

    def get(self, key: bytes) -> bytes | None:
        raise NotImplementedError

    def set(self, key: bytes, value: bytes) -> None:
        raise NotImplementedError

    def delete(self, key: bytes) -> None:
        raise NotImplementedError

    def keys(self) -> list[bytes]:
        raise NotImplementedError

    def flush(self) -> None:
        """Durability point (no-op for volatile implementations)."""

    def close(self) -> None:
        pass

    def __len__(self) -> int:
        return len(self.keys())


class MemoryKVStore(KVStore):
    """Volatile dict-backed store — the hermetic test double, and the
    cheapest way to share one T2 between in-process engine replicas."""

    def __init__(self):
        self._d: dict[bytes, bytes] = {}

    def get(self, key: bytes) -> bytes | None:
        return self._d.get(key)

    def set(self, key: bytes, value: bytes) -> None:
        self._d[bytes(key)] = bytes(value)

    def delete(self, key: bytes) -> None:
        self._d.pop(key, None)

    def keys(self) -> list[bytes]:
        return list(self._d)

    def __len__(self) -> int:
        return len(self._d)


def _frame(op: int, key: bytes, value: bytes) -> bytes:
    crc = zlib.crc32(bytes([op]))
    crc = zlib.crc32(struct.pack("<II", len(key), len(value)), crc)
    crc = zlib.crc32(key, crc)
    crc = zlib.crc32(value, crc)
    return _HEADER.pack(_MAGIC, op, len(key), len(value), crc) + key + value


def _scan(buf: bytes):
    """Yield ``(op, key, value, end_offset)`` for every intact record in
    ``buf``; stop (without raising) at the first torn/corrupt one."""
    off, n = 0, len(buf)
    while off + _HEADER.size <= n:
        magic, op, klen, vlen, crc = _HEADER.unpack_from(buf, off)
        end = off + _HEADER.size + klen + vlen
        if magic != _MAGIC or op not in (_OP_SET, _OP_DEL) or end > n:
            return
        key = buf[off + _HEADER.size:off + _HEADER.size + klen]
        value = buf[off + _HEADER.size + klen:end]
        want = zlib.crc32(bytes([op]))
        want = zlib.crc32(struct.pack("<II", klen, vlen), want)
        want = zlib.crc32(key, want)
        want = zlib.crc32(value, want)
        if want != crc:
            return
        yield op, key, value, end
        off = end


class DiskKVStore(KVStore):
    """Append-only segment log with crc32 records and atomic-rename
    compaction; see the module docstring for the recovery contract.

    ``compact_ratio``: auto-compact once dead (overwritten/deleted)
    bytes exceed this fraction of the log.  ``fsync``: fsync on every
    ``flush()`` (appends are buffered either way; callers that need a
    durability point call ``flush``).
    """

    def __init__(self, directory: str, compact_ratio: float = 0.5,
                 fsync: bool = False):
        self.dir = directory
        self.path = os.path.join(directory, "segments.log")
        self._fsync = fsync
        self._compact_ratio = compact_ratio
        self._index: dict[bytes, bytes] = {}
        self._dead_bytes = 0
        self.quarantined_bytes = 0          # torn-tail bytes set aside
        self.fail_after_bytes: int | None = None   # fault-injection hook
        os.makedirs(directory, exist_ok=True)
        self._recover()
        self._fh = open(self.path, "ab")

    # ------------------------------------------------------- recovery

    def _recover(self) -> None:
        if not os.path.exists(self.path):
            with open(self.path, "wb"):
                pass
            return
        with open(self.path, "rb") as f:
            buf = f.read()
        good = 0
        for op, key, value, end in _scan(buf):
            if key in self._index:
                self._dead_bytes += _HEADER.size + len(key) + \
                    len(self._index[key])
            if op == _OP_SET:
                self._index[key] = value
            else:
                self._index.pop(key, None)
                self._dead_bytes += end - good   # tombstone is dead weight
            good = end
        if good < len(buf):
            # torn or corrupt tail: quarantine it (diagnosable, never
            # served) and truncate the log to the last intact boundary
            tail = buf[good:]
            self.quarantined_bytes = len(tail)
            qpath = os.path.join(self.dir, f"quarantine-{good}.bin")
            with open(qpath, "wb") as q:
                q.write(tail)
            with open(self.path, "r+b") as f:
                f.truncate(good)

    # ------------------------------------------------------------- api

    def get(self, key: bytes) -> bytes | None:
        return self._index.get(key)

    def set(self, key: bytes, value: bytes) -> None:
        key, value = bytes(key), bytes(value)
        old = self._index.get(key)
        self._append(_frame(_OP_SET, key, value))
        if old is not None:
            self._dead_bytes += _HEADER.size + len(key) + len(old)
        self._index[key] = value
        self._maybe_compact()

    def delete(self, key: bytes) -> None:
        if key not in self._index:
            return
        rec = _frame(_OP_DEL, bytes(key), b"")
        self._append(rec)
        self._dead_bytes += _HEADER.size + len(key) + \
            len(self._index.pop(key)) + len(rec)
        self._maybe_compact()

    def keys(self) -> list[bytes]:
        return list(self._index)

    def __len__(self) -> int:
        return len(self._index)

    def flush(self) -> None:
        self._fh.flush()
        if self._fsync:
            os.fsync(self._fh.fileno())

    def close(self) -> None:
        self.flush()
        self._fh.close()

    # ------------------------------------------------------- internals

    def _append(self, rec: bytes) -> None:
        if self.fail_after_bytes is not None:
            cut = min(self.fail_after_bytes, len(rec))
            self._fh.write(rec[:cut])
            self._fh.flush()
            raise SimulatedCrash(f"fault injection: wrote {cut}/"
                                 f"{len(rec)} bytes")
        self._fh.write(rec)

    def _maybe_compact(self) -> None:
        live = sum(_HEADER.size + len(k) + len(v)
                   for k, v in self._index.items())
        if self._dead_bytes > 256 and \
                self._dead_bytes > self._compact_ratio * (live + 1):
            self.compact()

    def compact(self) -> None:
        """Rewrite the live index into a fresh log and publish it with
        an atomic rename — a crash mid-compaction leaves the old log
        untouched."""
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as f:
            for k, v in self._index.items():
                f.write(_frame(_OP_SET, k, v))
            f.flush()
            os.fsync(f.fileno())
        self._fh.close()
        os.replace(tmp, self.path)
        self._fh = open(self.path, "ab")
        self._dead_bytes = 0
