"""The staged serving pipeline: Route -> Cascade -> Execute -> Feedback.

``TryageEngine`` used to hard-wire this flow inside ``_route_admitted``
and ``_execute``; this module makes each stage an explicit object over a
shared typed context, so the flow reads top-to-bottom and new stages
(the Feedback stage that closes the online-adaptation loop is the first
beneficiary) slot in without touching the scheduler or the disciplines.

Two context types, matching the engine's two batch granularities:

* ``RouteContext`` — one *admission batch* flowing Route -> Cascade.
  Route fills router predictions and raw expert choices (cache-aware:
  hits skip scoring, misses are scored as one smaller batch); Cascade
  applies the abstention/escalation rule to freshly scored rows and
  memoises the post-cascade verdict.
* ``FlushContext`` — one *per-expert micro-batch* flowing Execute ->
  Feedback.  Execute launches the padded expert forward and materialises
  ``Result``s; Feedback publishes each observed (prompt, expert, loss)
  sample to the engine's replay buffer and gives the adaptation loop a
  chance to refresh the router.

Stages are deliberately thin orchestration over the engine's compute
primitives (``_score_batch``, ``_cascade``, ``_run_expert`` — the jit'd
functions live on the engine so compilation caches survive across
batches).  The split point between the halves is the scheduler: routed
requests wait in per-expert lanes between ``admit`` and ``flush``, so
Execute runs on micro-batches that mix requests from many admission
batches.

Behaviour contract: with adaptation disabled (``adapt_every=0``) and
``min_confidence=0`` the pipeline reproduces the pre-pipeline engine
bit-for-bit — identical choices, Results and EngineStats
(tests/test_pipeline.py enforces this against a reference
implementation of the old hard-wired flow).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import numpy as np

from repro.serving.cache import DecisionCache
from repro.serving.requests import Request, Result
from repro.serving.scheduler import LaneEntry

if TYPE_CHECKING:                                      # pragma: no cover
    from repro.serving.engine import TryageEngine


@dataclasses.dataclass
class RouteContext:
    """One admission batch flowing Route -> Cascade.

    ``pred``/``choice``/``cached``/``depth``/``confidence`` are dense
    per-request arrays (allocated by RouteStage); ``miss_idx`` lists the
    rows that were freshly scored this batch — the only rows Cascade
    touches, because cache hits already carry their post-cascade
    verdict.  ``keys`` holds the decision-cache keys (None when the
    cache is disabled).
    """

    reqs: list[Request]
    pred: np.ndarray | None = None          # (B, M) f32 router L-hat
    choice: np.ndarray | None = None        # (B,) i64 expert index
    cached: np.ndarray | None = None        # (B,) bool cache hits
    depth: np.ndarray | None = None         # (B,) i64 cascade depth
    confidence: np.ndarray | None = None    # (B,) f64 final confidence
    fallback_depth: np.ndarray | None = None  # (B,) i64 health fallbacks
    keys: list | None = None
    miss_idx: list[int] = dataclasses.field(default_factory=list)
    # row -> pooled router embedding, filled only when the semantic tier
    # is enabled (Cascade feeds these back into T3 at memoisation time)
    emb: dict | None = None
    # one-launch cascade payload: (rows, sigma, esc) when the Route
    # stage scored the misses through the fused cascade kernel — rows
    # lists the scored ctx indices (== miss_idx), sigma/esc are the
    # kernel's per-expert uncertainty and depth-1 escalation target
    # aligned with it.  None = staged scoring, Cascade runs the
    # sigma pass itself.
    fused: tuple | None = None


@dataclasses.dataclass
class FlushContext:
    """One per-expert micro-batch flowing Execute -> Feedback."""

    expert_idx: int
    entries: list[LaneEntry]
    reason: str
    results: list[Result] = dataclasses.field(default_factory=list)


class RouteStage:
    """Score an admission batch through the decision cache.

    Hits return their memoised post-cascade verdict; misses are scored
    as one (smaller) batch with the router.  The cache key carries the
    live router version (``engine.router_version``), so verdicts scored
    by a superseded router can never hit."""

    def __init__(self, engine: "TryageEngine"):
        self.eng = engine

    def __call__(self, ctx: RouteContext) -> RouteContext:
        eng = self.eng
        B = len(ctx.reqs)
        ctx.pred = np.zeros((B, eng.rc.n_models), np.float32)
        ctx.choice = np.zeros(B, np.int64)
        ctx.cached = np.zeros(B, bool)
        ctx.depth = np.zeros(B, np.int64)
        ctx.confidence = np.ones(B, np.float64)
        ctx.fallback_depth = np.zeros(B, np.int64)
        if eng.cache is None:
            pred, choice = self._score_rows(ctx, list(range(B)))
            ctx.pred[:] = pred
            ctx.choice[:] = choice
            ctx.miss_idx = list(range(B))
            return ctx
        sink = self._dropped_lambda_sink
        ctx.keys = [DecisionCache.key(r.tokens, r.lambdas, eng._cnames,
                                      r.min_confidence, eng.router_version,
                                      unknown_sink=sink)
                    for r in ctx.reqs]
        misses = []
        for i, key in enumerate(ctx.keys):
            hit, tier = eng.cache.lookup(key)
            if hit is None:
                misses.append(i)
            else:
                (ctx.pred[i], ctx.choice[i], ctx.depth[i],
                 ctx.confidence[i]) = hit
                ctx.cached[i] = True
                eng.stats.cache_tier_hits[tier] += 1
        if misses and getattr(eng.cache, "semantic", None) is not None:
            misses = self._semantic_probe(ctx, misses)
        if misses:
            if ctx.emb is not None:
                # embeddings already computed for the T3 probe: finish
                # the score from them (head + host constraint argmin)
                mpred, mchoice = eng._score_from_emb(
                    [ctx.reqs[i] for i in misses],
                    np.stack([ctx.emb[i] for i in misses]))
            else:
                mpred, mchoice = self._score_rows(ctx, misses)
            for j, i in enumerate(misses):
                ctx.pred[i] = mpred[j]
                ctx.choice[i] = mchoice[j]
        ctx.miss_idx = misses
        eng.stats.cache_hits += B - len(misses)
        eng.stats.cache_misses += len(misses)
        return ctx

    def _score_rows(self, ctx: RouteContext, rows: list[int]):
        """Score the given ctx rows as one batch — through the fused
        cascade kernel when the engine and the batch qualify (the
        sigma/escalation payload rides along on ``ctx.fused`` for the
        Cascade stage), through ``_score_batch`` otherwise."""
        eng = self.eng
        reqs = [ctx.reqs[i] for i in rows]
        if eng._use_fused_cascade(reqs):
            pred, choice, sigma, esc = eng._score_cascade_batch(reqs)
            ctx.fused = (list(rows), sigma, esc)
            return pred, choice
        return eng._score_batch(reqs)

    def _dropped_lambda_sink(self, names: list) -> None:
        self.eng.stats.cache_key_dropped_lambda += len(names)

    def _semantic_probe(self, ctx: RouteContext,
                        misses: list[int]) -> list[int]:
        """T3 pass over the exact-miss rows: one batched embedding pass,
        then a nearest-neighbour probe per row.  A hit adopts the
        cached post-cascade verdict (after revalidation against the
        live router version — see ``semcache.SemanticCache``); the
        remaining rows keep their embeddings in ``ctx.emb`` so scoring
        and T3 insertion reuse the encoder pass."""
        eng = self.eng
        emb = eng._embed_batch([ctx.reqs[i] for i in misses])
        ctx.emb = {i: emb[j] for j, i in enumerate(misses)}
        still = []
        for j, i in enumerate(misses):
            entry, status = eng.cache.lookup_semantic(
                emb[j], ctx.keys[i], eng.router_version)
            if status != "miss":
                eng.stats.cache_revalidations += 1
            if status == "hit":
                (ctx.pred[i], ctx.choice[i], ctx.depth[i],
                 ctx.confidence[i]) = entry
                ctx.cached[i] = True
                eng.stats.cache_tier_hits["t3"] += 1
                # promote into the exact tiers under this prompt's own
                # key: the next identical retry is a T1 hit, no
                # embedding pass needed
                eng.cache.put(ctx.keys[i], entry[0], entry[1],
                              int(entry[2]), float(entry[3]))
                continue
            if status == "stale":
                eng.stats.cache_revalidation_rejects += 1
            still.append(i)
        return still


class CascadeStage:
    """Apply the abstention/escalation rule to freshly scored rows and
    memoise the post-cascade verdict.

    Only ``miss_idx`` rows are cascaded — cache hits were stored *after*
    their cascade, so re-running it would double-escalate.  The
    single-shot fast path (no request carries a confidence floor) is
    inherited from ``engine._cascade``: the sigma pass is skipped and
    choices pass through untouched."""

    def __init__(self, engine: "TryageEngine"):
        self.eng = engine

    def __call__(self, ctx: RouteContext) -> RouteContext:
        eng = self.eng
        if not ctx.miss_idx:
            return ctx
        miss_reqs = [ctx.reqs[i] for i in ctx.miss_idx]
        mpred = ctx.pred[ctx.miss_idx]
        if ctx.fused is not None and ctx.fused[0] == ctx.miss_idx:
            # the Route stage already has sigma and the depth-1
            # escalation target from the fused kernel — resolve the
            # verdict without a second router pass
            _, sigma, esc = ctx.fused
            mchoice, mdepth, mconf = eng._cascade_fused(
                miss_reqs, mpred, ctx.choice[ctx.miss_idx], sigma, esc)
        else:
            mchoice, mdepth, mconf = eng._cascade(
                miss_reqs, mpred, ctx.choice[ctx.miss_idx])
        for j, i in enumerate(ctx.miss_idx):
            ctx.choice[i] = mchoice[j]
            ctx.depth[i] = mdepth[j]
            ctx.confidence[i] = mconf[j]
            if ctx.keys is not None:
                if ctx.emb is not None:
                    # semantic tier enabled: hand the row's embedding to
                    # the stack so T3 learns this verdict too
                    eng.cache.put(ctx.keys[i], mpred[j], mchoice[j],
                                  int(mdepth[j]), float(mconf[j]),
                                  emb=ctx.emb[i])
                else:
                    eng.cache.put(ctx.keys[i], mpred[j], mchoice[j],
                                  int(mdepth[j]), float(mconf[j]))
        return ctx


class FallbackStage:
    """Health consult: walk the fallback chain for requests whose chosen
    expert is unhealthy or saturated (``core.objective.fallback_choice``
    over ``engine.health``'s availability mask).

    Runs *after* the cache/cascade half on every row — cache hits
    included, because health is time-varying state that must never be
    memoised: the cache stores the pre-fallback verdict and this stage
    re-applies the current health picture to it.  With no health tracker
    attached (``engine.health is None``, the default) or with every
    expert available, the stage is a strict no-op — the parity contract
    with the health-unaware engine (tests/test_fallback.py) holds by
    construction."""

    def __init__(self, engine: "TryageEngine"):
        self.eng = engine

    def __call__(self, ctx: RouteContext) -> RouteContext:
        eng = self.eng
        if eng.health is None or eng.fallback_max_depth <= 0:
            return ctx
        avail = eng.health.available_mask()
        if avail.all():
            return ctx
        from repro.core.objective import fallback_choice
        from repro.serving.requests import lambda_matrix
        healthy = eng.health.healthy_mask()
        # the same constrained objective the Route stage minimised:
        # L-hat + sum_j lambda_j C_j, per request
        scores = ctx.pred + lambda_matrix(ctx.reqs, eng._cnames) @ eng._cmat
        for i in range(len(ctx.reqs)):
            final, fdepth, degraded = fallback_choice(
                scores[i], healthy, avail, int(ctx.choice[i]),
                eng._esc_order, eng.fallback_max_depth)
            if fdepth == 0:
                continue
            ctx.choice[i] = final
            ctx.fallback_depth[i] = fdepth
            eng.stats.fallbacks += 1
            eng.stats.fallback_depth_hist[fdepth] += 1
            if degraded:
                eng.stats.degraded += 1
        return ctx


class ExecuteStage:
    """Launch one padded per-expert micro-batch and materialise Results
    with true enqueue->flush latency; all execution telemetry
    (flushes, buckets, latencies, cascade histogram) lands here.

    On a mesh-backed engine the launch is a *dispatch*:
    ``engine._run_expert`` consults the placement map
    (``serving.placement.PlacementMap``) and commits the micro-batch to
    the least-busy device stream among the expert's replica slices —
    the stage itself is device-agnostic, which is exactly why the
    executor could be swapped under it without touching the flow."""

    def __init__(self, engine: "TryageEngine"):
        self.eng = engine

    def __call__(self, ctx: FlushContext) -> FlushContext:
        eng = self.eng
        e = eng.library[ctx.expert_idx]
        t0 = eng._now()
        preds, ex_loss, ex_acc = eng._run_expert(
            e, [en.req for en in ctx.entries])
        end = eng._now()
        eng.stats.expert_time_s += end - t0
        eng.stats.flushes[ctx.reason] += 1
        for j, en in enumerate(ctx.entries):
            r = en.req
            loss = acc = None
            if (r.targets is not None and r.mask is not None
                    and r.mask.astype(bool).any()):
                loss = float(ex_loss[j])
                acc = float(ex_acc[j])
            flops = 2.0 * e.n_params * len(r.tokens)
            latency = (max(end - r.arrival, 0.0) if r.arrival is not None
                       else end - t0)
            ctx.results.append(Result(
                uid=r.uid, expert=e.name, pred_losses=en.pred,
                predictions=preds[j], loss=loss, accuracy=acc,
                flops_proxy=flops, latency_s=latency, cached=en.cached,
                flush_reason=ctx.reason, cascade_depth=en.depth,
                confidence=en.confidence,
                fallback_depth=en.fallback_depth))
            eng.stats.served += 1
            eng.stats.per_expert[e.name] += 1
            eng.stats.total_flops += flops
            eng.stats.latencies.append(latency)
            eng.stats.cascade_depth_hist[en.depth] += 1
            eng.stats.tier_latencies[en.depth].append(latency)
            if en.depth > 0:
                eng.stats.escalations += 1
        return ctx


class FeedbackStage:
    """Close the loop: publish each observed (prompt, expert, loss)
    sample to the replay buffer and let the adaptation loop refresh the
    router.

    A sample is published only when the expert's loss was actually
    measured (``Result.loss`` is not None — the request carried MLM
    targets); samples whose token shape does not match the buffer's are
    dropped and counted (mixed-length traffic serves fine, it just
    cannot all feed one replay batch).  ``engine._maybe_adapt`` is a
    no-op unless the engine was built with ``adapt_every > 0``, so the
    feedback stage is free for frozen-router serving."""

    def __init__(self, engine: "TryageEngine"):
        self.eng = engine

    def __call__(self, ctx: FlushContext) -> FlushContext:
        eng = self.eng
        if eng.replay is None:
            return ctx
        for en, res in zip(ctx.entries, ctx.results):
            if res.loss is None:
                continue
            eng.replay.add(en.req.tokens, ctx.expert_idx, res.loss)
        eng.stats.feedback_events = eng.replay.seen
        eng.stats.feedback_dropped = eng.replay.dropped
        eng.stats.replay_len = len(eng.replay)
        eng.stats.replay_cap = eng.replay.capacity
        eng._maybe_adapt()
        return ctx


class ServingPipeline:
    """The five stages composed over one engine.

    ``admit``  runs Route -> Cascade -> Fallback on an admission batch
               and returns the filled RouteContext (the engine pushes
               the rows into scheduler lanes, or executes them directly
               under FIFO).  Fallback is a strict no-op without a
               health tracker, so the health-unaware pipeline is still
               the PR-4 Route -> Cascade flow bit-for-bit.
    ``flush``  runs Execute -> Feedback on one per-expert micro-batch
               and returns its Results.
    """

    def __init__(self, engine: "TryageEngine"):
        self.route = RouteStage(engine)
        self.cascade = CascadeStage(engine)
        self.fallback = FallbackStage(engine)
        self.execute = ExecuteStage(engine)
        self.feedback = FeedbackStage(engine)

    def admit(self, reqs: list[Request]) -> RouteContext:
        return self.fallback(self.cascade(self.route(RouteContext(reqs))))

    def flush(self, expert_idx: int, entries: list[LaneEntry],
              reason: str) -> list[Result]:
        ctx = FlushContext(expert_idx, entries, reason)
        return self.feedback(self.execute(ctx)).results
