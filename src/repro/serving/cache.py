"""Router-decision cache: the tiered ``DecisionCacheStack``.

Scoring is cheap per request but it is pure overhead when the same
prompt arrives again with the same constraint weights — a common shape
of production traffic (retries, template prompts, polling agents).
Three tiers answer progressively broader recurrence:

  T1  ``DecisionCache`` — the in-process exact LRU (unchanged
      semantics).  Keys on the exact token bytes plus the request's
      lambda vector (in engine constraint order), so a hit is
      guaranteed to return the identical ``(pred_losses, choice)`` the
      fresh score produced: no hash collisions, no approximate
      matching.
  T2  a persistent exact store behind the Valkey-shaped KV interface
      (``serving.kvstore``) — survives restarts and is shareable across
      engine replicas.  Same exact key, serialized; a T2 hit is
      promoted into T1.
  T3  ``serving.semcache.SemanticCache`` — approximate, keyed on
      router embeddings: nearest neighbour within a calibrated distance
      bound, revalidated against the live router version and the
      request's exact lambda/threshold context before use.

Capacity-bounded LRU (T1): reads refresh recency, inserts evict the
least recently used entry.  Hit/miss telemetry lives in ``EngineStats``,
not here — the engine is the only consumer.

Online adaptation: once the engine refreshes the router mid-stream
(``core.router.VersionedParams.swap``), every memoised verdict scored
by the superseded parameters is stale.  The router *version* is part of
the key — for T2 it is part of the serialized key bytes, for T3 it is
checked at revalidation — so stale entries become structurally
unreachable the moment the version bumps — correctness does not depend
on anyone remembering to flush.  The engine still calls ``clear()`` on
a swap to reclaim the dead in-memory entries immediately instead of
waiting for LRU churn; T2 records survive (they are unreachable under
the new version's keys, and a restarted or peer replica at the old
version may still legitimately read them).
"""

from __future__ import annotations

import logging
import struct
from collections import OrderedDict

import numpy as np

log = logging.getLogger(__name__)

# log-once registry for unknown constraint-flag spellings (module level
# so every cache instance shares it; tests reset it explicitly)
_warned_lambda_names: set[str] = set()


class DecisionCache:
    """LRU cache from (token bytes, lambda vector, confidence threshold)
    to the cascade's final routing verdict."""

    def __init__(self, capacity: int = 4096):
        assert capacity >= 1
        self.capacity = capacity
        self._entries: OrderedDict[tuple, tuple[np.ndarray, int, int, float]] = (
            OrderedDict()
        )

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def key(
        tokens: np.ndarray,
        lambdas: dict,
        constraint_names: list,
        min_confidence: float = 0.0,
        router_version: int = 0,
        unknown_sink=None,
    ) -> tuple:
        """Exact cache key: token buffer bytes (plus dtype/shape, so
        equal byte strings from different layouts cannot collide) + the
        lambda vector laid out in engine constraint order (unknown
        constraint names are ignored, matching ``lambda_matrix``) + the
        request's cascade threshold + the router version that scored the
        entry.  The threshold is part of the key because the cached
        verdict is *post-cascade*: the same prompt at a stricter
        threshold may legitimately escalate to a different expert, and
        cached verdicts must stay exact.  The version is part of the key
        because online adaptation swaps the router parameters
        mid-stream: a verdict scored by version ``v`` must never be
        returned once version ``v + 1`` is live.

        Lambda entries whose names are unknown to the engine's
        constraints cannot affect the verdict (``lambda_matrix`` drops
        them too), so they are dropped from the key — but never
        silently: each dropped name is warned once per process, and
        ``unknown_sink`` (when given) receives the list of dropped
        names so the engine can count them (the
        ``cache_key_dropped_lambda`` stat).  Without the observability,
        two requests with different misspelled flags collide onto one
        verdict and the typo is invisible."""
        unknown = [n for n in lambdas if n not in constraint_names]
        if unknown:
            if unknown_sink is not None:
                unknown_sink(unknown)
            for n in unknown:
                if n not in _warned_lambda_names:
                    _warned_lambda_names.add(n)
                    log.warning(
                        "decision-cache key: lambda flag %r does not match "
                        "any engine constraint %r — dropped (check the "
                        "flag spelling); further drops of this name are "
                        "counted but not logged",
                        n,
                        list(constraint_names),
                    )
        lam = tuple(float(lambdas.get(name, 0.0)) for name in constraint_names)
        return (
            tokens.tobytes(),
            tokens.dtype.str,
            tokens.shape,
            lam,
            float(min_confidence),
            int(router_version),
        )

    def get(self, key: tuple) -> tuple[np.ndarray, int, int, float] | None:
        """Return the memoised verdict (refreshing LRU recency) or None.

        The ``pred`` row is the stored array itself — read-only by
        construction (see ``put``), so sharing it is safe."""
        entry = self._entries.get(key)
        if entry is None:
            return None
        self._entries.move_to_end(key)
        return entry

    def lookup(self, key: tuple) -> tuple[tuple | None, str]:
        """Tier-attributed probe: ``(entry, "t1")`` on a hit, ``(None,
        "")`` on a miss — the uniform surface the Route stage uses so a
        plain cache and a ``DecisionCacheStack`` count tier telemetry
        identically."""
        entry = self.get(key)
        return entry, ("t1" if entry is not None else "")

    def put(
        self,
        key: tuple,
        pred: np.ndarray,
        choice: int,
        depth: int = 0,
        confidence: float = 1.0,
    ) -> None:
        # the stored pred row is handed back by reference on every hit;
        # freeze it so a caller mutating a hit raises instead of silently
        # corrupting all future hits for this key
        stored = np.array(pred, np.float32)
        stored.setflags(write=False)
        self._entries[key] = (
            stored,
            int(choice),
            int(depth),
            float(confidence),
        )
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)

    def stale_versions(self, live_version: int) -> set[int]:
        """Router versions present in stored keys that differ from the
        live one (the version is the key's last element).  Empty means
        the engine's post-swap invariant holds — every surviving entry
        was scored by the live snapshot."""
        return {k[-1] for k in self._entries} - {int(live_version)}

    def clear(self) -> None:
        """Drop every entry (memory reclaim after a router-version bump;
        the version in the key already guarantees stale entries cannot
        hit)."""
        self._entries.clear()


# --------------------------------------------------------------- codecs
#
# Stable binary encodings for the exact key and the verdict, used by the
# persistent T2 tier.  Hand-rolled length-prefixed framing (no pickle):
# the encoding is injective, byte-stable across processes and Python
# versions, and contains nothing executable.


def encode_key(key: tuple) -> bytes:
    """Serialize an exact decision-cache key tuple to stable bytes."""
    tok_bytes, dtype_str, shape, lam, min_conf, version = key
    dt = dtype_str.encode("utf-8")
    out = [struct.pack("<qdH", int(version), float(min_conf), len(lam))]
    out.append(struct.pack(f"<{len(lam)}d", *lam) if lam else b"")
    out.append(struct.pack("<H", len(dt)))
    out.append(dt)
    out.append(struct.pack("<H", len(shape)))
    out.append(struct.pack(f"<{len(shape)}q", *shape) if shape else b"")
    out.append(tok_bytes)
    return b"".join(out)


def encode_verdict(
    pred: np.ndarray, choice: int, depth: int, confidence: float
) -> bytes:
    """Serialize a routing verdict to stable bytes."""
    row = np.asarray(pred, np.float32).ravel()
    return (
        struct.pack("<qqdH", int(choice), int(depth), float(confidence), len(row))
        + row.astype("<f4").tobytes()
    )


def decode_verdict(buf: bytes) -> tuple[np.ndarray, int, int, float]:
    """Inverse of ``encode_verdict``; the returned pred row is frozen
    (read-only) like every cached verdict."""
    choice, depth, confidence, m = struct.unpack_from("<qqdH", buf)
    pred = np.frombuffer(buf, "<f4", count=m, offset=struct.calcsize("<qqdH"))
    pred = pred.astype(np.float32)
    pred.setflags(write=False)
    return pred, int(choice), int(depth), float(confidence)


class DecisionCacheStack:
    """Three-tier decision cache: T1 exact LRU, T2 persistent KV, T3
    semantic.

    Exact probes (``lookup``) walk T1 then T2, promoting a T2 hit into
    T1; the semantic tier is consulted separately (``lookup_semantic``)
    because it needs the request's router embedding, which the Route
    stage only computes for exact misses.  ``put`` writes every enabled
    tier.  The constructor signature is capacity-first and
    kwargs-optional so ``DecisionCacheStack(capacity)`` is a drop-in
    T1-only cache (bit-for-bit the plain ``DecisionCache`` behaviour —
    tests/test_cache_stack.py enforces the parity)."""

    key = staticmethod(DecisionCache.key)

    def __init__(self, capacity: int = 4096, kv=None, semantic=None):
        self.t1 = DecisionCache(capacity)
        self.kv = kv
        self.semantic = semantic

    @property
    def capacity(self) -> int:
        return self.t1.capacity

    def __len__(self) -> int:
        return len(self.t1)

    def get(self, key: tuple) -> tuple[np.ndarray, int, int, float] | None:
        return self.lookup(key)[0]

    def lookup(self, key: tuple) -> tuple[tuple | None, str]:
        """Exact-tier probe: ``(entry, tier)`` where tier is ``"t1"``
        or ``"t2"`` on a hit, ``(None, "")`` on a miss.  A T2 hit is
        promoted into T1 so the next probe is in-process."""
        entry = self.t1.get(key)
        if entry is not None:
            return entry, "t1"
        if self.kv is not None:
            buf = self.kv.get(encode_key(key))
            if buf is not None:
                pred, choice, depth, conf = decode_verdict(buf)
                self.t1.put(key, pred, choice, depth, conf)
                return self.t1.get(key), "t2"
        return None, ""

    def lookup_semantic(
        self, emb: np.ndarray, key: tuple, live_version: int
    ) -> tuple[tuple | None, str]:
        """T3 probe for one exact-miss row: nearest cached embedding
        under the same (lambda vector, threshold) context, within the
        calibrated bound, revalidated against ``live_version``.
        Returns ``(entry, status)`` — status ``"hit"``/``"stale"``/
        ``"miss"`` (``"off"`` without a semantic tier)."""
        if self.semantic is None:
            return None, "off"
        return self.semantic.get(emb, (key[3], key[4]), live_version)

    def put(
        self,
        key: tuple,
        pred: np.ndarray,
        choice: int,
        depth: int = 0,
        confidence: float = 1.0,
        emb: np.ndarray | None = None,
    ) -> None:
        self.t1.put(key, pred, choice, depth, confidence)
        if self.kv is not None:
            self.kv.set(
                encode_key(key), encode_verdict(pred, choice, depth, confidence)
            )
        if self.semantic is not None and emb is not None:
            # context = (lambda tuple, threshold); version = key's last
            # element, checked again at every semantic hit
            self.semantic.put(
                emb, (key[3], key[4]), key[-1], pred, choice, depth, confidence
            )

    def stale_versions(self, live_version: int) -> set[int]:
        """Stale router versions reachable by the *serving* tiers (T1 +
        T3).  T2 is exempt: its records are keyed by serialized version
        and can only be read back under the exact version that wrote
        them, so old-version records are unreachable here yet still
        valid for a peer/restarted replica at that version."""
        stale = self.t1.stale_versions(live_version)
        if self.semantic is not None:
            stale |= self.semantic.stale_versions(live_version)
        return stale

    def clear(self) -> None:
        """Drop the in-memory tiers (T1 + T3).  T2 survives — see
        ``stale_versions`` for why that is correct."""
        self.t1.clear()
        if self.semantic is not None:
            self.semantic.clear()

    def flush(self) -> None:
        """Durability point for the persistent tier (no-op without T2)."""
        if self.kv is not None:
            self.kv.flush()

    def close(self) -> None:
        if self.kv is not None:
            self.kv.close()
