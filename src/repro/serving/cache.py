"""Router-decision cache.

Scoring is cheap per request but it is pure overhead when the same
prompt arrives again with the same constraint weights — a common shape
of production traffic (retries, template prompts, polling agents).  The
cache keys on the exact token bytes plus the request's lambda vector
(in engine constraint order), so a hit is guaranteed to return the
identical ``(pred_losses, choice)`` the fresh score produced: no hash
collisions, no approximate matching.

Capacity-bounded LRU: reads refresh recency, inserts evict the least
recently used entry.  Hit/miss telemetry lives in ``EngineStats``, not
here — the engine is the only consumer.

Online adaptation: once the engine refreshes the router mid-stream
(``core.router.VersionedParams.swap``), every memoised verdict scored
by the superseded parameters is stale.  The router *version* is part of
the key, so stale entries become structurally unreachable the moment
the version bumps — correctness does not depend on anyone remembering
to flush.  The engine still calls ``clear()`` on a swap to reclaim the
dead entries' memory immediately instead of waiting for LRU churn.
"""

from __future__ import annotations

from collections import OrderedDict

import numpy as np


class DecisionCache:
    """LRU cache from (token bytes, lambda vector, confidence threshold)
    to the cascade's final routing verdict."""

    def __init__(self, capacity: int = 4096):
        assert capacity >= 1
        self.capacity = capacity
        self._entries: OrderedDict[tuple, tuple[np.ndarray, int, int, float]] = (
            OrderedDict()
        )

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def key(
        tokens: np.ndarray,
        lambdas: dict,
        constraint_names: list,
        min_confidence: float = 0.0,
        router_version: int = 0,
    ) -> tuple:
        """Exact cache key: token buffer bytes (plus dtype/shape, so
        equal byte strings from different layouts cannot collide) + the
        lambda vector laid out in engine constraint order (unknown
        constraint names are ignored, matching ``lambda_matrix``) + the
        request's cascade threshold + the router version that scored the
        entry.  The threshold is part of the key because the cached
        verdict is *post-cascade*: the same prompt at a stricter
        threshold may legitimately escalate to a different expert, and
        cached verdicts must stay exact.  The version is part of the key
        because online adaptation swaps the router parameters
        mid-stream: a verdict scored by version ``v`` must never be
        returned once version ``v + 1`` is live."""
        lam = tuple(float(lambdas.get(name, 0.0)) for name in constraint_names)
        return (
            tokens.tobytes(),
            tokens.dtype.str,
            tokens.shape,
            lam,
            float(min_confidence),
            int(router_version),
        )

    def get(self, key: tuple) -> tuple[np.ndarray, int, int, float] | None:
        """Return the memoised verdict (refreshing LRU recency) or None.

        The ``pred`` row is the stored array itself — read-only by
        construction (see ``put``), so sharing it is safe."""
        entry = self._entries.get(key)
        if entry is None:
            return None
        self._entries.move_to_end(key)
        return entry

    def put(
        self,
        key: tuple,
        pred: np.ndarray,
        choice: int,
        depth: int = 0,
        confidence: float = 1.0,
    ) -> None:
        # the stored pred row is handed back by reference on every hit;
        # freeze it so a caller mutating a hit raises instead of silently
        # corrupting all future hits for this key
        stored = np.array(pred, np.float32)
        stored.setflags(write=False)
        self._entries[key] = (
            stored,
            int(choice),
            int(depth),
            float(confidence),
        )
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)

    def stale_versions(self, live_version: int) -> set[int]:
        """Router versions present in stored keys that differ from the
        live one (the version is the key's last element).  Empty means
        the engine's post-swap invariant holds — every surviving entry
        was scored by the live snapshot."""
        return {k[-1] for k in self._entries} - {int(live_version)}

    def clear(self) -> None:
        """Drop every entry (memory reclaim after a router-version bump;
        the version in the key already guarantees stale entries cannot
        hit)."""
        self._entries.clear()
