"""Per-expert health and overload signals for the serving front end.

The routing objective assumes every expert in the library is equally
*servable*; production traffic breaks that assumption constantly — an
expert's deployment fails, its lane backs up behind a slow rollout, a
burst saturates one specialist while the rest idle.  This module is the
serving layer's model of that reality: one ``ExpertState`` per expert,
fed by three observation streams the engine already produces,

  * **lane depth** — pending occupancy of the expert's scheduler lanes,
    observed at every admission (EWMA; the overload signal),
  * **flush latency** — wall time of each executed micro-batch
    (EWMA; exported, and a slow-expert telemetry signal for operators),
  * **failures** — failed lane flushes (injected by tests/benchmarks
    through ``ExpertScheduler.inject_failures``, or real execution
    errors), tracked as an EWMA of the per-flush failure indicator
    (the health signal).

and two derived predicates the Route stage consults:

  ``healthy(i)``     the expert's failure EWMA is below threshold, its
                     circuit-breaker cooldown has expired, and it is not
                     administratively forced down.
  ``overloaded(i)``  the expert's lane-depth EWMA is at or above the
                     overload threshold.

``available(i) = healthy(i) and not overloaded(i)`` is the mask the
fallback chain routes around (``core.objective.fallback_choice``);
degraded mode falls back to the smallest *healthy* expert even when it
is overloaded, because answering slowly beats not answering.

Failure recovery is circuit-breaker shaped: a failure marks the expert
unhealthy for at least ``cooldown_s`` (no new traffic routes there, so
the EWMA cannot decay on its own); once the cooldown expires the expert
is half-open — traffic returns, and either successful flushes decay the
failure EWMA below threshold (closed) or the next failure re-opens the
breaker for another cooldown.

Everything here is host-side bookkeeping — no JAX, no device state —
so the all-healthy fast path costs one boolean mask read per admission
batch.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np


@dataclasses.dataclass
class ExpertState:
    """Mutable health record for one expert."""

    depth_ewma: float = 0.0       # smoothed pending-lane occupancy
    latency_ewma_s: float = 0.0   # smoothed flush wall time
    failure_ewma: float = 0.0     # smoothed failure indicator in [0, 1]
    flushes: int = 0              # successful flushes observed
    failures: int = 0             # failed flushes observed
    last_failure: float = -1.0    # engine-clock time of the last failure
    forced_down: bool = False     # administrative kill switch


class ExpertHealth:
    """Health/overload tracker over a library of ``n_experts``.

    Parameters
    ----------
    n_experts:       library size — one ``ExpertState`` per index.
    depth_alpha:     EWMA weight for lane-depth observations.
    latency_alpha:   EWMA weight for flush-latency observations.
    failure_alpha:   EWMA weight for the per-flush failure indicator;
                     0.5 means a single failure immediately trips the
                     default threshold and two clean flushes clear it.
    fail_threshold:  ``failure_ewma`` at or above this is unhealthy.
    overload_depth:  ``depth_ewma`` at or above this is overloaded;
                     size it to a few full buckets of backlog relative
                     to the engine's ``lane_target``.
    cooldown_s:      circuit-breaker hold-down after a failure; the
                     expert stays unhealthy at least this long even if
                     the EWMA would have decayed.
    now_fn:          clock (injectable for deterministic tests; the
                     engine passes its own clock so health time and
                     latency time agree).
    """

    def __init__(self, n_experts: int, depth_alpha: float = 0.3,
                 latency_alpha: float = 0.3, failure_alpha: float = 0.5,
                 fail_threshold: float = 0.5, overload_depth: float = 64.0,
                 cooldown_s: float = 30.0,
                 now_fn: Callable[[], float] = time.monotonic):
        assert n_experts >= 1
        assert 0.0 < depth_alpha <= 1.0 and 0.0 < latency_alpha <= 1.0
        assert 0.0 < failure_alpha <= 1.0 and fail_threshold > 0.0
        self.n_experts = n_experts
        self.depth_alpha = depth_alpha
        self.latency_alpha = latency_alpha
        self.failure_alpha = failure_alpha
        self.fail_threshold = fail_threshold
        self.overload_depth = overload_depth
        self.cooldown_s = cooldown_s
        self._now = now_fn
        self.states = [ExpertState() for _ in range(n_experts)]

    # ------------------------------------------------------ observations

    def observe_lane_depth(self, expert_idx: int, depth: int) -> None:
        """Fold one pending-lane occupancy sample into the depth EWMA
        (the engine reports every expert's depth at each admission, so
        idle lanes decay toward zero instead of freezing at their
        last-busy value)."""
        st = self.states[expert_idx]
        a = self.depth_alpha
        st.depth_ewma = (1.0 - a) * st.depth_ewma + a * float(depth)

    def observe_flush(self, expert_idx: int, latency_s: float,
                      ok: bool = True) -> None:
        """Fold one flush outcome in: wall time into the latency EWMA,
        the success/failure indicator into the failure EWMA."""
        st = self.states[expert_idx]
        if ok:
            a = self.latency_alpha
            st.latency_ewma_s = ((1.0 - a) * st.latency_ewma_s
                                 + a * float(latency_s))
            st.flushes += 1
        else:
            st.failures += 1
            st.last_failure = self._now()
        a = self.failure_alpha
        st.failure_ewma = ((1.0 - a) * st.failure_ewma
                           + a * (0.0 if ok else 1.0))

    def record_failure(self, expert_idx: int) -> None:
        """Shorthand for ``observe_flush(i, 0.0, ok=False)``."""
        self.observe_flush(expert_idx, 0.0, ok=False)

    def force_down(self, expert_idx: int, down: bool = True) -> None:
        """Administrative kill switch (and its release) — operators and
        benchmarks use this to take an expert out of rotation
        unconditionally, independent of the learned signals."""
        self.states[expert_idx].forced_down = down

    # -------------------------------------------------------- predicates

    def healthy(self, expert_idx: int) -> bool:
        st = self.states[expert_idx]
        if st.forced_down:
            return False
        if (st.last_failure >= 0.0
                and self._now() - st.last_failure < self.cooldown_s):
            return False
        return st.failure_ewma < self.fail_threshold

    def overloaded(self, expert_idx: int) -> bool:
        return self.states[expert_idx].depth_ewma >= self.overload_depth

    def available(self, expert_idx: int) -> bool:
        return self.healthy(expert_idx) and not self.overloaded(expert_idx)

    def healthy_mask(self) -> np.ndarray:
        return np.array([self.healthy(i) for i in range(self.n_experts)],
                        bool)

    def available_mask(self) -> np.ndarray:
        return np.array([self.available(i) for i in range(self.n_experts)],
                        bool)

    # -------------------------------------------------------- telemetry

    def snapshot(self) -> list[dict]:
        """Per-expert health telemetry (consumed by ``serving.metrics``
        and ``EngineStats.summary``)."""
        out = []
        for i, st in enumerate(self.states):
            out.append({
                "healthy": self.healthy(i),
                "overloaded": self.overloaded(i),
                "depth_ewma": round(st.depth_ewma, 4),
                "latency_ewma_s": round(st.latency_ewma_s, 6),
                "failure_ewma": round(st.failure_ewma, 4),
                "flushes": st.flushes,
                "failures": st.failures,
                "forced_down": st.forced_down,
            })
        return out
