"""Execution feedback: the signal source for online router adaptation.

Expert execution already measures, for every request that carries MLM
targets, the *observed* masked NLL of the expert that actually served
it.  That is exactly the supervision the router was trained on — a
(prompt, expert, loss) sample of the Q function — except it arrives for
free, continuously, from live traffic.  The pipeline's Feedback stage
publishes each such sample here; the adaptation loop replays bounded
batches of them through the update step built by
``core.training.make_router_update_step`` to keep loss predictions
tracking downstream expert performance under drift.

Design notes:

* **Bounded ring.**  The buffer keeps the most recent ``capacity``
  samples and drops the oldest — under a distribution shift the buffer
  composition converges to the new traffic within one capacity's worth
  of requests, which is what makes replayed updates *track* drift
  instead of averaging it away.
* **Bandit feedback.**  Only the chosen expert's loss is observed (the
  other experts never ran), so a replayed sample supervises a single
  entry of the router's prediction vector.  The escalation cascade and
  exploration in traffic provide the off-policy coverage.
* **Homogeneous sequence length.**  Samples are stacked into dense
  arrays for the jit'd update step, so all tokens in one buffer must
  share a sequence length.  The first sample fixes the shape; later
  samples with a different shape are *dropped and counted*
  (``ReplayBuffer.dropped``) rather than raised — mixed-length traffic
  is legal for serving, it just cannot all feed one replay batch.
"""

from __future__ import annotations

import numpy as np


class ReplayBuffer:
    """Bounded FIFO ring of feedback samples with batch sampling.

    ``add`` is O(1); ``sample`` draws a uniform batch (with replacement,
    so a fixed ``batch`` size — and therefore a single jit compilation
    of the update step — works at any occupancy >= 1).
    """

    def __init__(self, capacity: int = 4096):
        assert capacity >= 1
        self.capacity = capacity
        self.seen = 0                      # accepted samples, ever
        self.dropped = 0                   # shape-mismatched, ever
        self._tokens: list[np.ndarray] = []
        self._experts: list[int] = []
        self._losses: list[float] = []
        self._head = 0                     # ring cursor once full

    def __len__(self) -> int:
        return len(self._tokens)

    def add(self, tokens: np.ndarray, expert_idx: int,
            observed_loss: float) -> bool:
        """Publish one sample; returns False (counted in ``dropped``)
        when its shape does not match the buffer's first sample."""
        if self._tokens and tokens.shape != self._tokens[0].shape:
            self.dropped += 1
            return False
        tokens = np.array(tokens, copy=True)   # detach from the request
        self.seen += 1
        if len(self._tokens) < self.capacity:
            self._tokens.append(tokens)
            self._experts.append(int(expert_idx))
            self._losses.append(float(observed_loss))
        else:
            self._tokens[self._head] = tokens
            self._experts[self._head] = int(expert_idx)
            self._losses[self._head] = float(observed_loss)
            self._head = (self._head + 1) % self.capacity
        return True

    def sample(self, batch: int, rng: np.random.Generator,
               ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Uniform batch with replacement: ``(tokens (B, S) int,
        expert_idx (B,) int32, observed_loss (B,) float32)``."""
        assert len(self) >= 1, "cannot sample an empty replay buffer"
        idx = rng.integers(0, len(self), size=batch)
        return (np.stack([self._tokens[i] for i in idx]),
                np.array([self._experts[i] for i in idx], np.int32),
                np.array([self._losses[i] for i in idx], np.float32))
