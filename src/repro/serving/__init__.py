from repro.serving.cache import DecisionCache, DecisionCacheStack
from repro.serving.engine import TryageEngine, EngineStats, bucket_size
from repro.serving.feedback import ReplayBuffer
from repro.serving.frontend import AdmissionQueue, ServingFrontend, Session
from repro.serving.health import ExpertHealth, ExpertState
from repro.serving.kvstore import (DiskKVStore, KVStore, MemoryKVStore,
                                   SimulatedCrash)
from repro.serving.metrics import (MetricSpec, MetricsServer, metric_names,
                                   render, start_metrics_server)
from repro.serving.pipeline import (CascadeStage, ExecuteStage,
                                    FallbackStage, FeedbackStage,
                                    FlushContext, RouteContext, RouteStage,
                                    ServingPipeline)
from repro.serving.requests import (Request, Result, lambda_matrix,
                                    parse_flags)
from repro.serving.scheduler import ExpertScheduler, Lane, LaneEntry
from repro.serving.semcache import (ExactNNIndex, SemanticCache,
                                    calibrate_eps)

__all__ = ["TryageEngine", "EngineStats", "Request", "Result",
           "bucket_size", "lambda_matrix", "parse_flags", "DecisionCache", "DecisionCacheStack",
           "KVStore", "MemoryKVStore", "DiskKVStore", "SimulatedCrash",
           "SemanticCache", "ExactNNIndex", "calibrate_eps",
           "ExpertScheduler", "Lane", "LaneEntry",
           "ReplayBuffer", "ServingPipeline", "RouteContext",
           "FlushContext", "RouteStage", "CascadeStage", "ExecuteStage",
           "FeedbackStage", "FallbackStage",
           "ExpertHealth", "ExpertState",
           "ServingFrontend", "Session", "AdmissionQueue",
           "MetricSpec", "MetricsServer", "metric_names", "render",
           "start_metrics_server"]
