from repro.serving.engine import TryageEngine, EngineStats
from repro.serving.requests import Request, Result, parse_flags

__all__ = ["TryageEngine", "EngineStats", "Request", "Result", "parse_flags"]
