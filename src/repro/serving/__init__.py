from repro.serving.engine import TryageEngine, EngineStats, bucket_size
from repro.serving.requests import (Request, Result, lambda_matrix,
                                    parse_flags)

__all__ = ["TryageEngine", "EngineStats", "Request", "Result",
           "bucket_size", "lambda_matrix", "parse_flags"]
