from repro.serving.cache import DecisionCache
from repro.serving.engine import TryageEngine, EngineStats, bucket_size
from repro.serving.feedback import ReplayBuffer
from repro.serving.frontend import AdmissionQueue, ServingFrontend, Session
from repro.serving.health import ExpertHealth, ExpertState
from repro.serving.metrics import (MetricSpec, MetricsServer, metric_names,
                                   render, start_metrics_server)
from repro.serving.pipeline import (CascadeStage, ExecuteStage,
                                    FallbackStage, FeedbackStage,
                                    FlushContext, RouteContext, RouteStage,
                                    ServingPipeline)
from repro.serving.requests import (Request, Result, lambda_matrix,
                                    parse_flags)
from repro.serving.scheduler import ExpertScheduler, Lane, LaneEntry

__all__ = ["TryageEngine", "EngineStats", "Request", "Result",
           "bucket_size", "lambda_matrix", "parse_flags", "DecisionCache",
           "ExpertScheduler", "Lane", "LaneEntry",
           "ReplayBuffer", "ServingPipeline", "RouteContext",
           "FlushContext", "RouteStage", "CascadeStage", "ExecuteStage",
           "FeedbackStage", "FallbackStage",
           "ExpertHealth", "ExpertState",
           "ServingFrontend", "Session", "AdmissionQueue",
           "MetricSpec", "MetricsServer", "metric_names", "render",
           "start_metrics_server"]
