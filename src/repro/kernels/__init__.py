"""Pallas TPU kernels for the performance-critical hot spots.

  flash_attention/  block-wise online-softmax attention (train/prefill)
  router_score/     fused Tryage routing head: scores + constraint add +
                    argmin without an HBM round-trip
  mlstm_scan/       chunkwise-parallel mLSTM recurrence (xLSTM family)

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper) and ref.py (pure-jnp oracle).  Kernels compile on TPU/GPU
and fall back to interpret mode on CPU via ``default_interpret``; on TPU
the same BlockSpecs give VMEM-resident tiles with MXU-aligned
(128-multiple) matmul dims.
"""

from __future__ import annotations

import jax

_COMPILED_BACKENDS = ("tpu", "gpu")


def default_interpret(interpret: bool | None = None) -> bool:
    """Resolve an ``interpret`` argument for ``pl.pallas_call``.

    ``None`` means backend-detected: compiled where Pallas has a real
    lowering (TPU Mosaic, GPU Triton), interpret fallback on CPU.  An
    explicit bool always wins, so tests can force either mode.
    """
    if interpret is not None:
        return interpret
    return jax.default_backend() not in _COMPILED_BACKENDS
