"""Pallas TPU kernels for the performance-critical hot spots.

  flash_attention/  block-wise online-softmax attention (train/prefill)
  router_score/     fused Tryage routing head: scores + constraint add +
                    argmin without an HBM round-trip
  mlstm_scan/       chunkwise-parallel mLSTM recurrence (xLSTM family)

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper) and ref.py (pure-jnp oracle).  On this CPU container they
are validated with interpret=True; on TPU the same BlockSpecs give
VMEM-resident tiles with MXU-aligned (128-multiple) matmul dims.
"""
