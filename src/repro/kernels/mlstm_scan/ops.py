"""Model-layout wrapper: (B, S, H, dh) + state dict <-> kernel layout."""

from __future__ import annotations


from repro.kernels import sanitize, tiles
from repro.kernels.mlstm_scan.kernel import mlstm_chunkwise_bh


def mlstm_chunkwise(q, k, v, i_pre, f_pre, state, *, chunk=None,
                    interpret=None):
    """q/k/v: (B, S, H, dh) f32; i/f: (B, S, H); state: {"C","n","m"}.

    Returns (h (B, S, H, dh), new_state).  ``chunk=None`` consults the
    autotuned tile table (static default 64 as fallback).

    Under ``REPRO_SANITIZE=1`` (eager calls only) inputs, the incoming
    stabilizer state ``m`` (the exp exponent — out of ±MLSTM_M_RANGE
    means the renormalisation already broke down) and outputs are
    validated with checkify — see ``kernels.sanitize``.
    """
    B, S, H, dh = q.shape
    if chunk is None:
        # table-sourced chunks must satisfy the kernel's divisibility
        # assert; an incompatible entry falls back to the static default
        c = tiles.tile_for("mlstm_scan", B, "chunk", 64)
        chunk = c if S % min(c, S) == 0 else 64
    to_bh = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    to_bh2 = lambda a: a.transpose(0, 2, 1).reshape(B * H, S)
    h, C1, n1, m1 = mlstm_chunkwise_bh(
        to_bh(q), to_bh(k), to_bh(v), to_bh2(i_pre), to_bh2(f_pre),
        state["C"].reshape(B * H, dh, dh), state["n"].reshape(B * H, dh),
        state["m"].reshape(B * H), chunk=chunk, interpret=interpret)
    h = h.reshape(B, H, S, dh).transpose(0, 2, 1, 3)
    new_state = {"C": C1.reshape(B, H, dh, dh), "n": n1.reshape(B, H, dh),
                 "m": m1.reshape(B, H)}
    if (sanitize.sanitize_enabled()
            and sanitize.concrete(q, k, v, i_pre, f_pre, state, h)):
        R = sanitize.MLSTM_M_RANGE

        def _checks(q, k, v, ig, fg, m0, h, m1):
            sanitize.check_finite("mlstm_scan", "input", q, k, v, ig, fg)
            sanitize.check_in_range("mlstm_scan", "stabilizer state m",
                                    m0, -R, R)
            sanitize.check_finite("mlstm_scan", "output", h)
            sanitize.check_in_range("mlstm_scan", "new stabilizer state m",
                                    m1, -R, R)

        sanitize.run_checks(_checks, q, k, v, i_pre, f_pre, state["m"], h,
                            new_state["m"])
    return h, new_state
