"""Model-layout wrapper: (B, S, H, dh) + state dict <-> kernel layout."""

from __future__ import annotations


from repro.kernels.mlstm_scan.kernel import mlstm_chunkwise_bh


def mlstm_chunkwise(q, k, v, i_pre, f_pre, state, *, chunk=64,
                    interpret=None):
    """q/k/v: (B, S, H, dh) f32; i/f: (B, S, H); state: {"C","n","m"}.

    Returns (h (B, S, H, dh), new_state).
    """
    B, S, H, dh = q.shape
    to_bh = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    to_bh2 = lambda a: a.transpose(0, 2, 1).reshape(B * H, S)
    h, C1, n1, m1 = mlstm_chunkwise_bh(
        to_bh(q), to_bh(k), to_bh(v), to_bh2(i_pre), to_bh2(f_pre),
        state["C"].reshape(B * H, dh, dh), state["n"].reshape(B * H, dh),
        state["m"].reshape(B * H), chunk=chunk, interpret=interpret)
    h = h.reshape(B, H, S, dh).transpose(0, 2, 1, 3)
    new_state = {"C": C1.reshape(B, H, dh, dh), "n": n1.reshape(B, H, dh),
                 "m": m1.reshape(B, H)}
    return h, new_state
