"""Chunkwise-parallel mLSTM recurrence (xLSTM) in Pallas.

TPU adaptation of the xLSTM paper's fused CUDA recurrence: instead of a
per-timestep sequential loop (VPU-bound, no MXU work), the sequence is
processed in chunks of L timesteps.  Within a chunk the recurrence has a
closed form:

  lf_t = logsigmoid(f_t);  F_t = cumsum(lf)_t  (inclusive)
  g_t  = cummax(i_s - F_s)_t
  m_t  = F_t + max(m_prev, g_t)                       (stabilizer)
  num_t = e^{F_t + m_prev - m_t} q_t C_prev
        + sum_{s<=t} e^{F_t - F_s + i_s - m_t} (q_t.k_s) v_s
  den_t = e^{F_t + m_prev - m_t} q_t.n_prev
        + sum_{s<=t} e^{F_t - F_s + i_s - m_t} (q_t.k_s)
  h_t  = num_t / max(|den_t|, e^{-m_t})

so the inner sums become two (L,L)x(L,dh) matmuls on the MXU.  The grid is
(batch*heads,); a fori_loop walks chunks carrying (C, n, m) in VREG/VMEM.
Matches the sequential oracle (ref.py) to ~1e-5.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import default_interpret

NEG_INF = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref, c0_ref, n0_ref, m0_ref,
                  h_ref, c1_ref, n1_ref, m1_ref, *, chunk, seq_len):
    dh = q_ref.shape[-1]
    scale = 1.0 / math.sqrt(dh)
    n_chunks = seq_len // chunk

    def body(ci, carry):
        C, n, m = carry                                   # (dh,dh),(dh,),()
        # leading dim indexed with pl.ds(0, 1), not a python int: interpret
        # mode's load/store discharge rejects scalar ints inside fori_loop
        sl = (pl.ds(0, 1), pl.ds(ci * chunk, chunk), slice(None))
        q = pl.load(q_ref, sl)[0] * scale                 # (L, dh)
        k = pl.load(k_ref, sl)[0]
        v = pl.load(v_ref, sl)[0]
        ig = pl.load(i_ref, (pl.ds(0, 1),
                             pl.ds(ci * chunk, chunk)))[0]        # (L,)
        fg = pl.load(f_ref, (pl.ds(0, 1),
                             pl.ds(ci * chunk, chunk)))[0]

        lf = jax.nn.log_sigmoid(fg)
        F = jnp.cumsum(lf)                                # inclusive (L,)
        g = jax.lax.cummax(ig - F, axis=0)
        m_t = F + jnp.maximum(m, g)                       # (L,)

        # inter-chunk term
        w_inter = jnp.exp(F + m - m_t)                    # (L,)
        qC = jax.lax.dot_general(q, C, (((1,), (0,)), ((), ())))  # (L, dh)
        num = w_inter[:, None] * qC
        den = w_inter * jax.lax.dot_general(q, n[:, None],
                                            (((1,), (0,)), ((), ())))[:, 0]

        # intra-chunk term: W[t,s] = exp(F_t - F_s + i_s - m_t), s <= t
        logw = (F - m_t)[:, None] + (ig - F)[None, :]     # (L, L)
        t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
        logw = jnp.where(s_idx <= t_idx, logw, NEG_INF)
        W = jnp.exp(logw)
        S = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (L, L)
        WS = W * S
        num = num + jax.lax.dot_general(WS, v, (((1,), (0,)), ((), ())))
        den = den + WS.sum(axis=1)

        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[:, None]
        pl.store(h_ref, sl, h[None].astype(h_ref.dtype))

        # end-of-chunk state
        m_last = m_t[-1]
        w_state = jnp.exp((F[-1] - F) + ig - m_last)      # (L,)
        C_new = jnp.exp(F[-1] + m - m_last) * C + jax.lax.dot_general(
            k * w_state[:, None], v, (((0,), (0,)), ((), ())))
        n_new = jnp.exp(F[-1] + m - m_last) * n + (k * w_state[:, None]).sum(0)
        return C_new, n_new, m_last

    C0 = c0_ref[0].astype(jnp.float32)
    n0 = n0_ref[0].astype(jnp.float32)
    m0 = m0_ref[0, 0]
    C, n, m = jax.lax.fori_loop(0, n_chunks, body, (C0, n0, m0))
    c1_ref[0] = C.astype(c1_ref.dtype)
    n1_ref[0] = n.astype(n1_ref.dtype)
    m1_ref[0, 0] = m


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunkwise_bh(q, k, v, i_pre, f_pre, C0, n0, m0, *, chunk=64,
                       interpret=None):
    """q/k/v: (BH, S, dh) f32; i/f: (BH, S); C0 (BH, dh, dh); n0 (BH, dh);
    m0 (BH,).  Returns (h (BH, S, dh), C1, n1, m1)."""
    interpret = default_interpret(interpret)
    BH, S, dh = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    m0_2d = m0[:, None]
    kernel = functools.partial(_mlstm_kernel, chunk=chunk, seq_len=S)
    h, C1, n1, m1 = pl.pallas_call(
        kernel,
        grid=(BH,),
        in_specs=[
            pl.BlockSpec((1, S, dh), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, S, dh), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, S, dh), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, S), lambda b: (b, 0)),
            pl.BlockSpec((1, S), lambda b: (b, 0)),
            pl.BlockSpec((1, dh, dh), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, dh), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, dh), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, dh, dh), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, dh), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, dh), jnp.float32),
            jax.ShapeDtypeStruct((BH, dh, dh), jnp.float32),
            jax.ShapeDtypeStruct((BH, dh), jnp.float32),
            jax.ShapeDtypeStruct((BH, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, i_pre, f_pre, C0, n0, m0_2d)
    return h, C1, n1, m1[:, 0]
