"""Sequential oracle for the chunkwise mLSTM kernel (same math as
repro.models.ssm._mlstm_cell_seq, in (BH, S, dh) layout)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mlstm_ref(q, k, v, i_pre, f_pre, C0, n0, m0):
    """q/k/v: (BH, S, dh); i/f: (BH, S). Returns (h, C1, n1, m1)."""
    BH, S, dh = q.shape
    scale = 1.0 / math.sqrt(dh)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, ft = xs
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        f_act = jnp.exp(logf + m - m_new)
        i_act = jnp.exp(it - m_new)
        C = f_act[:, None, None] * C + i_act[:, None, None] * (
            kt[:, :, None] * vt[:, None, :])
        n = f_act[:, None] * n + i_act[:, None] * kt
        qs = qt * scale
        num = jnp.einsum("bkv,bk->bv", C, qs)
        den = jnp.abs(jnp.einsum("bk,bk->b", n, qs))
        den = jnp.maximum(den, jnp.exp(-m_new))
        return (C, n, m_new), num / den[:, None]

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, i_pre, f_pre))
    (C, n, m), h = jax.lax.scan(step, (C0, n0, m0), xs)
    return jnp.moveaxis(h, 0, 1), C, n, m
