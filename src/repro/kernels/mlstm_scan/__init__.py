from repro.kernels.mlstm_scan.ops import mlstm_chunkwise

__all__ = ["mlstm_chunkwise"]
