from repro.kernels.router_score.ops import router_head, router_route

__all__ = ["router_head", "router_route"]
