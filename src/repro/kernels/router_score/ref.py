"""Pure-jnp oracle for the fused routing head."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def router_score_ref(emb, w1, b1, w2, b2, cvals, lam):
    emb = emb.astype(jnp.float32)
    h = jax.nn.gelu(emb @ w1 + b1)
    pred = jax.nn.softplus(h @ w2 + b2)
    combined = pred + lam.astype(jnp.float32) @ cvals
    return pred, jnp.argmin(combined, axis=1).astype(jnp.int32)
