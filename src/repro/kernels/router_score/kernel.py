"""Fused Tryage routing head.

The routing decision is latency-critical (it sits in front of every
request) and tiny: pooled embedding (B, d) -> gelu MLP -> softplus ->
predicted losses (B, M) -> + lambda-weighted constraints -> argmin.  Done
naively that is four kernel launches and three HBM round-trips of (B, M)
intermediates.  Here the whole head runs in one Pallas program per batch
tile: both matmuls hit the MXU from VMEM-resident weights (d, hidden and M
are small), and the constraint-add + argmin happen in VREGs.  Outputs are
the scores (for telemetry/Pareto sweeps) and the selected expert index.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import default_interpret


def _router_kernel(emb_ref, w1_ref, b1_ref, w2_ref, b2_ref, cvals_ref,
                   lam_ref, scores_ref, choice_ref):
    emb = emb_ref[...].astype(jnp.float32)               # (bb, d)
    h = jax.lax.dot_general(emb, w1_ref[...],
                            (((1,), (0,)), ((), ()))) + b1_ref[...]
    h = jax.nn.gelu(h)
    raw = jax.lax.dot_general(h, w2_ref[...],
                              (((1,), (0,)), ((), ()))) + b2_ref[...]
    pred = jax.nn.softplus(raw)                          # (bb, M)
    scores_ref[...] = pred
    # constraint add: lam (bb, n_c), cvals (n_c, M)
    combined = pred + jax.lax.dot_general(
        lam_ref[...].astype(jnp.float32), cvals_ref[...],
        (((1,), (0,)), ((), ())))
    choice_ref[...] = jnp.argmin(combined, axis=1).astype(jnp.int32)


def launch_plan(B: int, block_b: int) -> dict:
    """Effective launch geometry for a batch-tiled routing kernel.

    ``block_b`` is silently clamped to the batch (a tile larger than B
    would be all padding), so the *requested* tile and the tile that
    actually ran can differ.  This is the single source of truth both
    kernels and the autotuner use: tile-table entries record
    ``effective_block_b`` from here, so they cannot lie about what ran.

    Returns ``{"block_b": effective tile, "padded_batch": B + pad,
    "grid": padded_batch // effective tile}``.
    """
    eff = max(1, min(int(block_b), int(B)))
    padded = B + (-B) % eff
    return {"block_b": eff, "padded_batch": padded, "grid": padded // eff}


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def router_score_fused(emb, w1, b1, w2, b2, cvals, lam, *, block_b=128,
                       interpret=None):
    """emb (B, d); cvals (n_c, M); lam (B, n_c).

    Returns (pred_losses (B, M) f32, choice (B,) int32).  ``interpret=None``
    picks compiled on TPU/GPU, interpret on CPU.
    """
    interpret = default_interpret(interpret)
    B, d = emb.shape
    M = w2.shape[1]
    n_c = cvals.shape[0]
    plan = launch_plan(B, block_b)
    block_b = plan["block_b"]
    pad = plan["padded_batch"] - B
    if pad:
        emb = jnp.pad(emb, ((0, pad), (0, 0)))
        lam = jnp.pad(lam, ((0, pad), (0, 0)))
    Bp = emb.shape[0]
    hidden = w1.shape[1]
    scores, choice = pl.pallas_call(
        _router_kernel,
        grid=(plan["grid"],),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((d, hidden), lambda i: (0, 0)),
            pl.BlockSpec((hidden,), lambda i: (0,)),
            pl.BlockSpec((hidden, M), lambda i: (0, 0)),
            pl.BlockSpec((M,), lambda i: (0,)),
            pl.BlockSpec((n_c, M), lambda i: (0, 0)),
            pl.BlockSpec((block_b, n_c), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, M), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, M), jnp.float32),
            jax.ShapeDtypeStruct((Bp,), jnp.int32),
        ],
        interpret=interpret,
    )(emb, w1, b1, w2, b2, cvals, lam)
    return scores[:B], choice[:B]
