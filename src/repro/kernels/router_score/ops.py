"""Public wrappers used by core.router / serving.engine."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.router_score.kernel import router_score_fused


def router_head(emb, head_params, interpret=True):
    """Predicted losses only (no constraints)."""
    M = head_params["w2"].shape[1]
    cvals = jnp.zeros((1, M), jnp.float32)
    lam = jnp.zeros((emb.shape[0], 1), jnp.float32)
    pred, _ = router_score_fused(emb, head_params["w1"], head_params["b1"],
                                 head_params["w2"], head_params["b2"],
                                 cvals, lam, interpret=interpret)
    return pred


def router_route(emb, head_params, constraints, lambdas, interpret=True):
    """Full fused decision. constraints: (n_c, M) np/jnp; lambdas: (B, n_c)."""
    pred, choice = router_score_fused(
        emb, head_params["w1"], head_params["b1"], head_params["w2"],
        head_params["b2"], jnp.asarray(constraints, jnp.float32),
        jnp.asarray(lambdas, jnp.float32), interpret=interpret)
    return pred, choice
