"""Public wrappers used by core.router / serving.engine."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import sanitize, tiles
from repro.kernels.router_score.kernel import launch_plan, router_score_fused


def decision_plan(B: int, block_b: int | None = None) -> dict:
    """The launch geometry a ``router_route`` call with this batch would
    use — tile-table consult included — so callers (engine stats, the
    autotuner) can report the *effective* tile, not the requested one."""
    if block_b is None:
        block_b = tiles.tile_for("router_score", B, "block_b", 128)
    return launch_plan(B, block_b)


def router_route_checks(pred, choice, emb, head_params, lambdas) -> None:
    """Trace-level sanitizer conditions for one fused routing decision.

    Callers evaluating under their own ``checkify`` (the engine's
    sanitized decide path) reuse this; eager callers get it through
    ``router_route`` when ``REPRO_SANITIZE=1``."""
    M = head_params["w2"].shape[1]
    sanitize.check_finite("router_score", "input", emb, lambdas,
                          *head_params.values())
    sanitize.check_finite("router_score", "predicted losses", pred)
    sanitize.check_in_range("router_score", "expert choice", choice, 0, M)


def router_head(emb, head_params, interpret=None):
    """Predicted losses only (no constraints)."""
    M = head_params["w2"].shape[1]
    cvals = jnp.zeros((1, M), jnp.float32)
    lam = jnp.zeros((emb.shape[0], 1), jnp.float32)
    pred, _ = router_score_fused(emb, head_params["w1"], head_params["b1"],
                                 head_params["w2"], head_params["b2"],
                                 cvals, lam, interpret=interpret)
    return pred


def router_route(emb, head_params, constraints, lambdas, *, block_b=None,
                 interpret=None):
    """Full fused decision: one Pallas program per batch tile computes
    MLP head -> softplus -> per-request lambda-weighted constraint add ->
    argmin, with no host round-trip between scoring and selection.

    constraints: (n_c, M) np/jnp; lambdas: (B, n_c).
    Returns (pred_losses (B, M) f32, choice (B,) int32).
    ``block_b=None`` consults the autotuned tile table (static default
    128 as fallback); an explicit tile is used as-is.
    """
    lam = jnp.asarray(lambdas, jnp.float32)
    if block_b is None:
        block_b = tiles.tile_for("router_score", emb.shape[0],
                                 "block_b", 128)
    pred, choice = router_score_fused(
        emb, head_params["w1"], head_params["b1"], head_params["w2"],
        head_params["b2"], jnp.asarray(constraints, jnp.float32),
        lam, block_b=block_b, interpret=interpret)
    if sanitize.sanitize_enabled() and sanitize.concrete(emb, pred, choice):
        sanitize.run_checks(
            lambda p, c, e, lm: router_route_checks(p, c, e, head_params,
                                                    lm),
            pred, choice, emb, lam)
    return pred, choice
