"""Block-wise online-softmax attention (FlashAttention) in Pallas.

TPU adaptation: the grid is (batch*heads, q-blocks); each program holds a
(block_q, head_dim) query tile in VMEM and streams K/V tiles of
(block_k, head_dim) through VMEM with a fori_loop, maintaining the online
softmax (running max m, normalizer l, accumulator acc) in VREGs.  Block
sizes default to 128 — MXU-aligned on both matmul dims.  Causal masking,
sliding windows and logit softcap (Grok) are folded into the inner loop.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import default_interpret

NEG_INF = -2.3819763e38


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, window,
                 softcap, block_q, block_k, seq_len_kv):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale            # (bq, hd)
    bq, hd = q.shape
    n_kb = seq_len_kv // block_k

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)

    def body(kb, carry):
        m, l, acc = carry
        # leading dim indexed with pl.ds(0, 1), not a python int: interpret
        # mode's load discharge rejects scalar int indices inside fori_loop
        k = pl.load(k_ref, (pl.ds(0, 1), pl.ds(kb * block_k, block_k),
                            slice(None)))[0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.ds(0, 1), pl.ds(kb * block_k, block_k),
                            slice(None)))[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (bq, block_k), 1)
        ok = jnp.ones((bq, block_k), jnp.bool_)
        if causal:
            ok &= k_pos <= q_pos
        if window > 0:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = corr * l + p.sum(axis=1)
        acc_new = corr[:, None] * acc + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_k", "interpret"))
def flash_attention_bhsd(q, k, v, *, causal=True, window=0, softcap=0.0,
                         block_q=128, block_k=128, interpret=None):
    """q/k/v: (BH, S, hd) with identical head counts. Returns (BH, S, hd)."""
    interpret = default_interpret(interpret)
    BH, S, hd = q.shape
    T = k.shape[1]
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, seq_len_kv=T)
    return pl.pallas_call(
        kernel,
        grid=(BH, S // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, T, hd), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((1, T, hd), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
