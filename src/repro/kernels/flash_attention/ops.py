"""Public wrapper: model-layout (B, S, H, hd) GQA flash attention."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=128, block_k=128, interpret=None):
    """q: (B, S, H, hd); k/v: (B, T, KV, hd) with H % KV == 0.

    Returns (B, S, H, hd).  GQA is handled by repeating K/V heads before
    the kernel (the kernel itself is per-(batch*head)).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    to_bh = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, a.shape[1], hd)
    out = flash_attention_bhsd(
        to_bh(q), to_bh(k), to_bh(v), causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k,
        interpret=interpret)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
