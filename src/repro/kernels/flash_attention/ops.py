"""Public wrapper: model-layout (B, S, H, hd) GQA flash attention."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import sanitize, tiles
from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=None, block_k=None, interpret=None):
    """q: (B, S, H, hd); k/v: (B, T, KV, hd) with H % KV == 0.

    Returns (B, S, H, hd).  GQA is handled by repeating K/V heads before
    the kernel (the kernel itself is per-(batch*head)).

    ``block_q``/``block_k`` default to the autotuned tile table (static
    128 as fallback); explicit values are used as-is.

    Under ``REPRO_SANITIZE=1`` (eager calls only) the inputs, the window
    bound and the output are validated with checkify — see
    ``kernels.sanitize``.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    # table-sourced tiles must satisfy the kernel's divisibility assert;
    # an incompatible entry falls back to the static default
    if block_q is None:
        bq = tiles.tile_for("flash_attention", B, "block_q", 128)
        block_q = bq if S % min(bq, S) == 0 else 128
    if block_k is None:
        bk = tiles.tile_for("flash_attention", B, "block_k", 128)
        block_k = bk if T % min(bk, T) == 0 else 128
    KV = k.shape[2]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    to_bh = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, a.shape[1], hd)
    out = flash_attention_bhsd(
        to_bh(q), to_bh(k), to_bh(v), causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k,
        interpret=interpret)
    out = out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    if sanitize.sanitize_enabled() and sanitize.concrete(q, k, v, out):
        T = k.shape[1]

        def _checks(q, k, v, w, out):
            sanitize.check_finite("flash_attention", "input", q, k, v)
            # window == 0 disables banding; valid band widths are 0..T
            sanitize.check_in_range("flash_attention", "window", w, 0, T + 1)
            sanitize.check_finite("flash_attention", "output", out)

        sanitize.run_checks(_checks, q, k, v, jnp.asarray(window), out)
    return out
