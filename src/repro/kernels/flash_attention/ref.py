"""Pure-jnp oracle for flash attention."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q/k/v: (BH, S, hd). Full-softmax reference."""
    BH, S, hd = q.shape
    T = k.shape[1]
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= kj <= qi
    if window > 0:
        ok &= kj > qi - window
    s = jnp.where(ok, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,btd->bsd", w, v.astype(jnp.float32)).astype(q.dtype)
