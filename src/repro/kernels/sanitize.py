"""Runtime sanitizer for the Pallas kernels: checkify-backed NaN/inf and
out-of-range checks, off by default.

Enable with ``REPRO_SANITIZE=1`` in the environment, ``--sanitize`` on
``launch/serve.py``, or programmatically with ``set_sanitize(True)``.
With the switch off every wrapper returns the exact same jit'd program
as before — the checks are never traced, so the fast path costs nothing.

Design constraint: on the pinned jax, ``checkify.checkify`` cannot
transform a function *containing* ``pl.pallas_call`` (the error carry
gets woven into the kernel's internal stateful jaxpr and the transform
rejects it).  The sanitizer therefore never wraps a kernel directly —
it runs the kernel un-transformed and evaluates an explicit pre/post
condition function (inputs + outputs) under ``checkify``; that is also
why ``ERRORS`` is ``user_checks`` only (automatic ``float_checks``
instrumentation hits the same wall).  Checks fire at *eager* call
boundaries: a sanitized wrapper invoked inside an outer ``jax.jit``
skips its checks (``concrete`` guard) — the caller owns sanitization
there, which is how ``serving.engine`` wires its decide path.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax.experimental import checkify

ERRORS = checkify.user_checks

#: exp(m) over/underflows f32 beyond ~88; a stabilizer state outside
#: this band means the scan's renormalisation has already broken down.
MLSTM_M_RANGE = 80.0

_override: bool | None = None


def set_sanitize(on: bool | None) -> None:
    """Force the sanitizer on/off for this process (None: back to env)."""
    global _override
    _override = on


def sanitize_enabled() -> bool:
    if _override is not None:
        return _override
    return os.environ.get("REPRO_SANITIZE", "").strip().lower() in (
        "1", "true", "on", "yes")


def concrete(*trees) -> bool:
    """True when no leaf is a tracer — checks only run at eager
    boundaries (see module docstring)."""
    return not any(isinstance(leaf, jax.core.Tracer)
                   for leaf in jax.tree.leaves(trees))


# ---------------------------------------------------- trace-level checks

def check_finite(kernel: str, label: str, *arrays) -> None:
    ok = jnp.bool_(True)
    for a in arrays:
        ok = ok & jnp.isfinite(jnp.asarray(a)).all()
    checkify.check(ok, f"{kernel}: non-finite {label}")


def check_in_range(kernel: str, label: str, x, lo, hi) -> None:
    x = jnp.asarray(x)
    ok = ((x >= lo) & (x < hi)).all()
    checkify.check(ok, f"{kernel}: {label} out of range [{lo}, {hi})")


def run_checks(check_fn, *arrays) -> None:
    """Evaluate a trace-level check function eagerly and throw on the
    first failed check (``checkify.JaxRuntimeError``)."""
    err, _ = checkify.checkify(check_fn, errors=ERRORS)(*arrays)
    err.throw()
