"""Autotuned tile-table consultation for the Pallas kernels.

``launch/autotune.py`` sweeps tile candidates per (backend, kernel,
batch) and persists the winners to ``experiments/tryage/tile_table.json``
(override with the ``REPRO_TILE_TABLE`` env var or ``set_table_path``,
e.g. from ``launch/serve.py --tile-table``).  The kernel ops wrappers
call ``tile_for`` when the caller left the tile argument at ``None``:
a missing/unreadable table, an unknown kernel, or a foreign backend all
fall back to the static default — consultation can *never* raise, and a
caller who passes an explicit tile is never second-guessed.

Table schema (see ``launch.autotune.write_table``)::

    {"version": 1,
     "<backend>": {"<kernel>": {"<batch>": {"block_b": 256,
                                            "effective_block_b": 256,
                                            ...timings...}}}}

Lookup picks the largest tabulated batch <= the requested batch (the
tile that won at 4k is the best prior for 5k), else the smallest entry.
"""

from __future__ import annotations

import json
import os
import threading

DEFAULT_PATH = os.path.join("experiments", "tryage", "tile_table.json")
ENV_VAR = "REPRO_TILE_TABLE"

_lock = threading.Lock()
_override_path: str | None = None
# (path, mtime) -> parsed table; None caches a failed load so a missing
# table costs one stat per call, not a re-parse attempt
_cache: dict = {}


def set_table_path(path: str | None) -> None:
    """Process-wide table override (``--tile-table``); ``None`` restores
    the env-var/default resolution."""
    global _override_path
    with _lock:
        _override_path = path
        _cache.clear()


def table_path() -> str:
    if _override_path is not None:
        return _override_path
    return os.environ.get(ENV_VAR, DEFAULT_PATH)


def load_table(path: str | None = None) -> dict | None:
    """The parsed tile table, or None when absent/unreadable.  Cached on
    (path, mtime) so serving-path consults cost one ``os.stat``."""
    path = path or table_path()
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        return None
    key = (path, mtime)
    with _lock:
        if key in _cache:
            return _cache[key]
    try:
        with open(path) as f:
            table = json.load(f)
        if not isinstance(table, dict):
            table = None
    except (OSError, ValueError):
        table = None
    with _lock:
        _cache.clear()
        _cache[key] = table
    return table


def _backend() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:                                  # pragma: no cover
        return "cpu"


def tile_for(kernel: str, batch: int, param: str, default: int,
             backend: str | None = None, path: str | None = None) -> int:
    """The tuned value of ``param`` for ``kernel`` at ``batch`` on this
    backend, or ``default`` when the table has nothing to say."""
    table = load_table(path)
    if table is None:
        return default
    entries = table.get(backend or _backend(), {}).get(kernel)
    if not isinstance(entries, dict) or not entries:
        return default
    batches = sorted(int(b) for b in entries if str(b).isdigit())
    if not batches:
        return default
    at_most = [b for b in batches if b <= int(batch)]
    pick = at_most[-1] if at_most else batches[0]
    entry = entries[str(pick)]
    val = entry.get(param) if isinstance(entry, dict) else None
    return int(val) if isinstance(val, (int, float)) else default
