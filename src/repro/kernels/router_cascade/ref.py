"""Pure-jnp oracle for the fused cascade decision head."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.router import UNC_FLOOR


def router_score_cascade_ref(emb, w1, b1, w2, b2, uw1, ub1, uw2, ub2,
                             cvals, lam, ladder_pos):
    emb = emb.astype(jnp.float32)
    h = jax.nn.gelu(emb @ w1 + b1)
    pred = jax.nn.softplus(h @ w2 + b2)
    hu = jax.nn.gelu(emb @ uw1 + ub1)
    sigma = jax.nn.softplus(hu @ uw2 + ub2) + UNC_FLOOR
    combined = pred + lam.astype(jnp.float32) @ cvals
    choice = jnp.argmin(combined, axis=1).astype(jnp.int32)
    pos = jnp.asarray(ladder_pos, jnp.int32)
    pos_choice = pos[choice]                             # (B,)
    above = pos[None, :] > pos_choice[:, None]           # (B, M)
    masked = jnp.where(above, combined, jnp.inf)
    minval = jnp.min(masked, axis=1, keepdims=True)
    M = combined.shape[1]
    cand_pos = jnp.where(masked == minval, pos[None, :], M)
    best_pos = jnp.min(cand_pos, axis=1)
    ids = jnp.arange(M, dtype=jnp.int32)[None, :]
    esc = jnp.sum(jnp.where(pos[None, :] == best_pos[:, None], ids, 0),
                  axis=1)
    esc = jnp.where(above.any(axis=1), esc, choice).astype(jnp.int32)
    return pred, sigma, choice, esc
