"""Fused one-launch cascade decision kernel (see kernel.py)."""
