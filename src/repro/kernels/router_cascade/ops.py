"""Public wrapper for the one-launch cascade decision head."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import sanitize, tiles
from repro.kernels.router_cascade.kernel import router_score_cascade_fused
from repro.kernels.router_score.kernel import launch_plan


def decision_plan(B: int, block_b: int | None = None) -> dict:
    """The launch geometry a ``router_route_cascade`` call with this
    batch would use — tile-table consult included — so callers (engine
    stats, the autotuner) can report the *effective* tile, not the
    requested one."""
    if block_b is None:
        block_b = tiles.tile_for("router_cascade", B, "block_b", 128)
    return launch_plan(B, block_b)


def router_route_cascade(emb, head_params, unc_params, constraints,
                         lambdas, ladder_pos, *, block_b=None,
                         interpret=None):
    """Full fused cascade decision: one Pallas program per batch tile
    computes loss head, uncertainty head, constrained argmin and the
    router-preferred depth-1 escalation target.

    constraints: (n_c, M); lambdas: (B, n_c); ladder_pos: (M,) int —
    expert -> position in the size-sorted escalation ladder.
    ``block_b=None`` consults the autotuned tile table (static default
    128 as fallback).
    Returns ``(pred (B, M) f32, sigma (B, M) f32, choice (B,) int32,
    esc (B,) int32)``.
    """
    lam = jnp.asarray(lambdas, jnp.float32)
    if block_b is None:
        block_b = tiles.tile_for("router_cascade", emb.shape[0],
                                 "block_b", 128)
    pred, sigma, choice, esc = router_score_cascade_fused(
        emb, head_params["w1"], head_params["b1"], head_params["w2"],
        head_params["b2"], unc_params["w1"], unc_params["b1"],
        unc_params["w2"], unc_params["b2"],
        jnp.asarray(constraints, jnp.float32), lam,
        jnp.asarray(ladder_pos, jnp.int32), block_b=block_b,
        interpret=interpret)
    if (sanitize.sanitize_enabled()
            and sanitize.concrete(emb, pred, sigma, choice, esc)):
        M = head_params["w2"].shape[1]

        def _checks(p, s, c, e):
            sanitize.check_finite("router_cascade", "predicted losses", p)
            sanitize.check_finite("router_cascade", "sigma", s)
            sanitize.check_in_range("router_cascade", "expert choice",
                                    c, 0, M)
            sanitize.check_in_range("router_cascade", "escalation target",
                                    e, 0, M)

        sanitize.run_checks(_checks, pred, sigma, choice, esc)
    return pred, sigma, choice, esc
