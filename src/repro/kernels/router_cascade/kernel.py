"""One-launch cascade decision: scores + confidence + depth-1 escalation.

``router_score_fused`` resolves the single-shot decision in one Pallas
program, but cascade traffic (requests carrying ``min_confidence > 0``)
still pays a second encoder pass for the uncertainty head and a host
round-trip before its escalation verdict lands.  This kernel extends the
fused head so the whole depth-<=1 verdict comes out of a single launch:

  * loss head      gelu MLP -> softplus -> predicted losses (bb, M)
  * uncertainty    the same MLP shape over the same embedding tile ->
                   sigma (bb, M) (softplus + UNC_FLOOR, matching
                   ``core.router.uncertainty_from_emb``)
  * selection      constraint add + argmin -> first-pick expert
  * escalation     masked re-argmin of the constrained scores over the
                   experts strictly *above* the first pick in the
                   size-sorted escalation ladder -> the router-preferred
                   depth-1 escalation target

The escalation target replicates ``core.objective.cascade_choice``'s
router-preferred step exactly, including its tie-break: among
equal-scoring larger experts the one *earliest in the ladder* wins (the
host walk argmins over ``order[pos+1:]``, first occurrence first).  The
kernel reproduces that by taking the score minimum and then the minimum
ladder position among the argmin set.  When the first pick is already
the top rung, ``esc`` echoes ``choice`` (there is nowhere to go — the
host walk stops too).

Whether a request actually escalates (its confidence vs. threshold) is
resolved by the caller: thresholds are per-request scalars, cheap on the
host, and keeping them out of the kernel means one compiled program
serves every traffic mix.  Depth >= 2 escalations fall back to the
staged host walk (``serving.engine._cascade_fused``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.router import UNC_FLOOR
from repro.kernels import default_interpret
from repro.kernels.router_score.kernel import launch_plan


def _cascade_kernel(emb_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                    uw1_ref, ub1_ref, uw2_ref, ub2_ref,
                    cvals_ref, lam_ref, pos_ref,
                    pred_ref, sigma_ref, choice_ref, esc_ref):
    emb = emb_ref[...].astype(jnp.float32)               # (bb, d)
    h = jax.lax.dot_general(emb, w1_ref[...],
                            (((1,), (0,)), ((), ()))) + b1_ref[...]
    h = jax.nn.gelu(h)
    raw = jax.lax.dot_general(h, w2_ref[...],
                              (((1,), (0,)), ((), ()))) + b2_ref[...]
    pred = jax.nn.softplus(raw)                          # (bb, M)
    pred_ref[...] = pred
    # uncertainty head on the same embedding tile (sigma > 0 via the
    # softplus floor, identical math to uncertainty_from_emb)
    hu = jax.lax.dot_general(emb, uw1_ref[...],
                             (((1,), (0,)), ((), ()))) + ub1_ref[...]
    hu = jax.nn.gelu(hu)
    uraw = jax.lax.dot_general(hu, uw2_ref[...],
                               (((1,), (0,)), ((), ()))) + ub2_ref[...]
    sigma_ref[...] = jax.nn.softplus(uraw) + UNC_FLOOR   # (bb, M)
    # constrained selection: lam (bb, n_c) @ cvals (n_c, M)
    combined = pred + jax.lax.dot_general(
        lam_ref[...].astype(jnp.float32), cvals_ref[...],
        (((1,), (0,)), ((), ())))
    choice = jnp.argmin(combined, axis=1).astype(jnp.int32)
    choice_ref[...] = choice
    # depth-1 escalation: re-argmin over experts strictly later in the
    # escalation ladder than the first pick.  pos_ref holds each
    # expert's ladder position (the inverse permutation of the order).
    M = combined.shape[1]
    ids = jax.lax.broadcasted_iota(jnp.int32, combined.shape, 1)
    pos = pos_ref[...].astype(jnp.int32)[None, :]        # (1, M)
    # ladder position of each row's first pick, via one-hot contraction
    # (gathers are awkward on the TPU vector unit; M is tiny)
    pos_choice = jnp.sum(
        jnp.where(ids == choice[:, None], pos, 0), axis=1)  # (bb,)
    above = pos > pos_choice[:, None]                    # (bb, M)
    big = jnp.full_like(combined, jnp.inf)
    masked = jnp.where(above, combined, big)
    minval = jnp.min(masked, axis=1, keepdims=True)
    # tie-break to the earliest ladder rung among the argmin set — the
    # host walk's np.argmin over order[pos+1:] (first occurrence) exactly
    cand_pos = jnp.where(masked == minval, pos, jnp.int32(M))
    best_pos = jnp.min(cand_pos, axis=1)                 # (bb,)
    esc = jnp.sum(jnp.where(pos == best_pos[:, None], ids, 0), axis=1)
    has_next = above.any(axis=1)
    esc_ref[...] = jnp.where(has_next, esc, choice).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def router_score_cascade_fused(emb, w1, b1, w2, b2, uw1, ub1, uw2, ub2,
                               cvals, lam, ladder_pos, *, block_b=128,
                               interpret=None):
    """emb (B, d); loss head w1/b1/w2/b2; uncertainty head uw1/ub1/uw2/
    ub2 (same shapes); cvals (n_c, M); lam (B, n_c); ladder_pos (M,)
    int32 — each expert's position in the size-sorted escalation ladder.

    Returns ``(pred (B, M) f32, sigma (B, M) f32, choice (B,) int32,
    esc (B,) int32)`` where ``esc`` is the router-preferred depth-1
    escalation target (== ``choice`` when the pick is the top rung).
    ``interpret=None`` picks compiled on TPU/GPU, interpret on CPU.
    """
    interpret = default_interpret(interpret)
    B, d = emb.shape
    M = w2.shape[1]
    n_c = cvals.shape[0]
    plan = launch_plan(B, block_b)
    block_b = plan["block_b"]
    pad = plan["padded_batch"] - B
    if pad:
        emb = jnp.pad(emb, ((0, pad), (0, 0)))
        lam = jnp.pad(lam, ((0, pad), (0, 0)))
    Bp = emb.shape[0]
    hidden = w1.shape[1]
    pred, sigma, choice, esc = pl.pallas_call(
        _cascade_kernel,
        grid=(plan["grid"],),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((d, hidden), lambda i: (0, 0)),
            pl.BlockSpec((hidden,), lambda i: (0,)),
            pl.BlockSpec((hidden, M), lambda i: (0, 0)),
            pl.BlockSpec((M,), lambda i: (0,)),
            pl.BlockSpec((d, hidden), lambda i: (0, 0)),
            pl.BlockSpec((hidden,), lambda i: (0,)),
            pl.BlockSpec((hidden, M), lambda i: (0, 0)),
            pl.BlockSpec((M,), lambda i: (0,)),
            pl.BlockSpec((n_c, M), lambda i: (0, 0)),
            pl.BlockSpec((block_b, n_c), lambda i: (i, 0)),
            pl.BlockSpec((M,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, M), lambda i: (i, 0)),
            pl.BlockSpec((block_b, M), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, M), jnp.float32),
            jax.ShapeDtypeStruct((Bp, M), jnp.float32),
            jax.ShapeDtypeStruct((Bp,), jnp.int32),
            jax.ShapeDtypeStruct((Bp,), jnp.int32),
        ],
        interpret=interpret,
    )(emb, w1, b1, w2, b2, uw1, ub1, uw2, ub2, cvals, lam, ladder_pos)
    return pred[:B], sigma[:B], choice[:B], esc[:B]
