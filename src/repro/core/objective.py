"""The routing objective (paper eq. 1 / eq. 4).

    M-hat = argmin_i [ L-hat(z, M_i) + sum_j lambda_j * C_j(M_i) ]

Constraints are scalar functions of expert metadata; the user supplies
weights lambda_j (via flags in the prompt, or programmatically).  With a
ground-truth Q table this is the Oracle router R_O; with router-predicted
losses it is the predictive router R_P.

Confidence-aware extension: the router's loss predictions carry no
notion of their own reliability, so a misprediction commits the prompt
to the wrong expert with full conviction.  Given a per-expert
predictive-uncertainty estimate sigma (``core.router`` uncertainty
head), this module derives a calibrated confidence score
``1 / (1 + sigma)`` in (0, 1), an optional confidence-penalized variant
of the routing score (``routing_scores(..., uncertainty, risk_weight)``),
and the abstention/escalation rule the serving cascade applies: when the
chosen expert's confidence falls below a request's threshold, walk the
size-ordered escalation ladder to the next-larger expert until the
router is confident enough (or the bounded depth / largest expert is
reached).  The walk is cycle-safe by construction — positions in the
ladder strictly increase.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.library import ModelLibrary


@dataclasses.dataclass
class Constraint:
    name: str
    values: np.ndarray  # (n_models,) scalar C_j(M_i)

    @staticmethod
    def from_fn(name: str, library: ModelLibrary, fn: Callable) -> "Constraint":
        return Constraint(name, np.array([fn(e) for e in library.experts], float))


def size_constraint(library: ModelLibrary) -> Constraint:
    """Linear size penalty C(M_i) = |W_i| / max|W_i| (paper §Pareto)."""
    sizes = library.sizes()
    return Constraint("size", sizes / sizes.max())


def log_size_constraint(library: ModelLibrary) -> Constraint:
    sizes = library.sizes()
    return Constraint("log_size", np.log(sizes) / np.log(sizes).max())


def recency_constraint(library: ModelLibrary) -> Constraint:
    """Penalize stale models: C = 1 - recency."""
    return Constraint("recency", 1.0 - library.recencies())


def constraint_matrix(constraints: Sequence[Constraint],
                      n_models: int) -> np.ndarray:
    """Stack constraint value vectors into the (n_c, M) matrix the fused
    router kernel consumes.  With no constraints, returns one zero row so
    the kernel's BlockSpec stays well-formed (the matching lambda column
    is zero too, so the decision is unaffected).
    """
    if not constraints:
        return np.zeros((1, n_models), np.float32)
    return np.stack([np.asarray(c.values, np.float32) for c in constraints])


def routing_scores(pred_losses, constraints: Sequence[Constraint],
                   lambdas: Sequence[float], uncertainty=None,
                   risk_weight: float = 0.0):
    """(…, n_models) combined routing loss L_R.

    With ``uncertainty`` (per-expert sigma, same shape as
    ``pred_losses``) and ``risk_weight > 0`` the score is
    confidence-penalized: experts whose loss prediction the router
    distrusts are handicapped by ``risk_weight * sigma`` — an upper-
    confidence-bound flavour of eq. 1.  The default (no uncertainty or
    zero weight) reproduces the original objective exactly.
    """
    assert len(constraints) == len(lambdas)
    score = jnp.asarray(pred_losses)
    for c, lam in zip(constraints, lambdas):
        score = score + lam * jnp.asarray(c.values, score.dtype)
    if uncertainty is not None and risk_weight:
        score = score + risk_weight * jnp.asarray(uncertainty, score.dtype)
    return score


def route(pred_losses, constraints: Sequence[Constraint] = (),
          lambdas: Sequence[float] = (), uncertainty=None,
          risk_weight: float = 0.0):
    """argmin of the routing objective. pred_losses: (…, n_models)."""
    return jnp.argmin(routing_scores(pred_losses, constraints, lambdas,
                                     uncertainty, risk_weight), axis=-1)


# ------------------------------------------------- confidence & cascade

def confidence_scores(uncertainty):
    """Map per-expert sigma >= 0 to a calibrated confidence in (0, 1].

    ``1 / (1 + sigma)`` is monotone-decreasing in sigma and unit-free:
    sigma is in the same log-loss units as L-hat, so confidence 0.5
    means "the router expects to be off by about one full unit of loss".
    """
    return 1.0 / (1.0 + np.maximum(np.asarray(uncertainty, np.float64), 0.0))


def escalation_order(library: ModelLibrary) -> list:
    """Expert indices sorted by ascending size — the cascade ladder.

    Ties keep library order (stable sort), so the ladder is a strict
    total order and escalation cannot revisit an expert."""
    return [int(i) for i in
            np.argsort(library.sizes(), kind="stable")]


def fallback_choice(scores, healthy, available, choice: int,
                    order: Sequence[int], max_depth: int,
                    ) -> tuple[int, int, bool]:
    """Health-aware fallback: final ``(expert, depth, degraded)`` for one
    request whose objective-chosen expert may be down or saturated.

    ``scores`` is the request's constrained routing score vector
    ``L-hat + sum_j lambda_j C_j`` (n_models,); ``healthy`` and
    ``available`` are boolean masks over the library (``available`` =
    healthy *and* not overloaded — the set the serving layer is willing
    to route new traffic to).  Starting from the objective's ``choice``:

    * If the choice is available (or fallback is disabled via
      ``max_depth <= 0``) it passes through untouched, depth 0 — the
      all-healthy fast path is a no-op by construction.
    * Otherwise the chain walks: exclude the current pick, re-score the
      same objective over the remaining experts (argmin of ``scores``,
      ties to the lowest index), and repeat while the fresh pick is
      still unavailable, up to ``max_depth`` exclusions.  Because each
      step takes the global argmin of the non-excluded set, the first
      *available* expert the walk reaches is exactly the argmin of the
      objective restricted to available experts — fallback never
      re-ranks the healthy field, it only removes the sick one
      (property-tested bit-for-bit against that masked re-score in
      ``tests/test_fallback.py``).
    * If the walk exhausts its budget (or every expert is unavailable),
      *graceful degraded mode*: serve the smallest healthy expert
      (first healthy rung of the size-sorted ``order``), overloaded or
      not — keeping the system answering beats honouring the objective.
      With no healthy expert at all the smallest expert overall is
      returned; the caller decides whether to serve or fail it.

    ``depth`` counts expert re-selections (0 = original pick served)
    and is monotone along the chain; a degraded pick that lands on a
    different expert counts as one more step.
    """
    if max_depth <= 0 or available[choice]:
        return int(choice), 0, False
    s = np.asarray(scores, np.float64)
    cur = int(choice)
    excluded = {cur}
    depth = 0
    while depth < max_depth and len(excluded) < len(s):
        cand = [i for i in range(len(s)) if i not in excluded]
        cur = min(cand, key=lambda i: (s[i], i))
        depth += 1
        if available[cur]:
            return cur, depth, False
        excluded.add(cur)
    # degraded: smallest healthy expert, else smallest expert overall
    final = next((int(i) for i in order if healthy[i]), int(order[0]))
    if final != cur:
        depth += 1
    return final, depth, True


def cascade_choice(choice: int, confidence, min_confidence: float,
                   order: Sequence[int], max_depth: int,
                   scores=None) -> tuple[int, int]:
    """Abstention/escalation rule: final (expert, depth) for one request.

    Starting from the objective's ``choice``, abstain and escalate while
    the router's confidence in the current expert is below
    ``min_confidence``, for at most ``max_depth`` steps.  Each step
    targets a *strictly larger* expert (later in the size-sorted
    ``order``): the literal next rung by default, or — when the
    request's constrained routing ``scores`` (n_models,) are supplied —
    the router-preferred larger expert, i.e. the best-scoring one among
    those above the current rung.  Router-preferred escalation spends
    the extra parameters where the router expects them to help instead
    of walking blindly into a wrong-domain specialist.

    ``min_confidence <= 0`` disables the cascade (single-shot behaviour,
    depth 0).  Bounded and cycle-safe either way: the ladder position
    strictly increases and the walk stops at the largest expert.
    """
    if min_confidence <= 0.0 or max_depth <= 0:
        return int(choice), 0
    conf = np.asarray(confidence, np.float64)
    pos = order.index(int(choice))
    depth = 0
    while (conf[order[pos]] < min_confidence and pos + 1 < len(order)
           and depth < max_depth):
        if scores is None:
            pos += 1
        else:
            rest = order[pos + 1:]
            s = np.asarray(scores, np.float64)
            pos += 1 + int(np.argmin([s[i] for i in rest]))
        depth += 1
    return int(order[pos]), depth
