"""The routing objective (paper eq. 1 / eq. 4).

    M-hat = argmin_i [ L-hat(z, M_i) + sum_j lambda_j * C_j(M_i) ]

Constraints are scalar functions of expert metadata; the user supplies
weights lambda_j (via flags in the prompt, or programmatically).  With a
ground-truth Q table this is the Oracle router R_O; with router-predicted
losses it is the predictive router R_P.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.library import ModelLibrary


@dataclasses.dataclass
class Constraint:
    name: str
    values: np.ndarray  # (n_models,) scalar C_j(M_i)

    @staticmethod
    def from_fn(name: str, library: ModelLibrary, fn: Callable) -> "Constraint":
        return Constraint(name, np.array([fn(e) for e in library.experts], float))


def size_constraint(library: ModelLibrary) -> Constraint:
    """Linear size penalty C(M_i) = |W_i| / max|W_i| (paper §Pareto)."""
    sizes = library.sizes()
    return Constraint("size", sizes / sizes.max())


def log_size_constraint(library: ModelLibrary) -> Constraint:
    sizes = library.sizes()
    return Constraint("log_size", np.log(sizes) / np.log(sizes).max())


def recency_constraint(library: ModelLibrary) -> Constraint:
    """Penalize stale models: C = 1 - recency."""
    return Constraint("recency", 1.0 - library.recencies())


def constraint_matrix(constraints: Sequence[Constraint],
                      n_models: int) -> np.ndarray:
    """Stack constraint value vectors into the (n_c, M) matrix the fused
    router kernel consumes.  With no constraints, returns one zero row so
    the kernel's BlockSpec stays well-formed (the matching lambda column
    is zero too, so the decision is unaffected).
    """
    if not constraints:
        return np.zeros((1, n_models), np.float32)
    return np.stack([np.asarray(c.values, np.float32) for c in constraints])


def routing_scores(pred_losses, constraints: Sequence[Constraint],
                   lambdas: Sequence[float]):
    """(…, n_models) combined routing loss L_R."""
    assert len(constraints) == len(lambdas)
    score = jnp.asarray(pred_losses)
    for c, lam in zip(constraints, lambdas):
        score = score + lam * jnp.asarray(c.values, score.dtype)
    return score


def route(pred_losses, constraints: Sequence[Constraint] = (),
          lambdas: Sequence[float] = ()):
    """argmin of the routing objective. pred_losses: (…, n_models)."""
    return jnp.argmin(routing_scores(pred_losses, constraints, lambdas), axis=-1)
