"""End-to-end co-training of router + experts (paper eq. 4/5).

Each step: (i) the router routes a batch of prompts (eq. 4); (ii) every
selected expert takes a gradient step on the prompts routed to it (eq. 5);
(iii) the router takes a gradient step towards the *freshly measured*
losses of all experts on the batch (eq. 2).  Updates are decoupled, as the
paper prescribes, so experts self-organize (SOM-style) toward the prompt
distribution the router sends them.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.library import ModelLibrary
from repro.core.qtable import _per_prompt_metrics_jit
from repro.core.router import RouterConfig, predict_losses
from repro.core.training import router_loss
from repro.data.batching import BatchIterator
from repro.data.corpus import DomainCorpus
from repro.models.model import lm_loss
from repro.optim import adamw_init, adamw_update


@dataclasses.dataclass
class E2EState:
    router_params: dict
    router_opt: object
    expert_opts: list
    history: list = dataclasses.field(default_factory=list)


def _expert_step_fn(cfg):
    @jax.jit
    def step(params, opt, batch):
        (loss, _), g = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch, remat=False), has_aux=True)(params)
        p2, o2 = adamw_update(params, g, opt, lr=5e-4, weight_decay=1e-5)
        return p2, o2, loss
    return step


def cotrain(library: ModelLibrary, router_params, rc: RouterConfig,
            corpus: DomainCorpus, *, steps=50, batch=32, seq=128, seed=0,
            router_lr=5e-5, verbose=False) -> E2EState:
    st = E2EState(router_params=router_params,
                  router_opt=adamw_init(router_params),
                  expert_opts=[adamw_init(e.params) for e in library.experts])
    uniform = {d: 1.0 / 8 for d in corpus.tables}
    it = BatchIterator(corpus, uniform, batch, seq, seed=seed)
    expert_steps = [_expert_step_fn(e.cfg) for e in library.experts]

    @jax.jit
    def router_step(p, o, toks, targets):
        l, g = jax.value_and_grad(
            lambda pp: router_loss(pp, rc, {"tokens": toks}, targets))(p)
        p2, o2 = adamw_update(p, g, o, lr=router_lr, weight_decay=1e-5)
        return p2, o2, l

    score = jax.jit(lambda p, toks: predict_losses(p, rc, {"tokens": toks}))

    for step_i in range(steps):
        b = next(it)
        toks = jnp.asarray(b["tokens"])
        # (eq. 4) route
        pred = np.asarray(score(st.router_params, toks))
        choice = pred.argmin(axis=1)
        # (eq. 5) update each selected expert on its routed prompts
        for mi in np.unique(choice):
            idx = np.where(choice == mi)[0]
            sub = {k: jnp.asarray(v[idx]) for k, v in b.items()
                   if k != "domain"}
            e = library.experts[int(mi)]
            e.params, st.expert_opts[mi], _ = expert_steps[int(mi)](
                e.params, st.expert_opts[mi], sub)
        # (eq. 2) refresh measured losses, update router toward them
        losses = np.stack(
            [np.asarray(_per_prompt_metrics_jit(
                e.params, e.cfg,
                {k: jnp.asarray(v) for k, v in b.items() if k != "domain"})[0])
             for e in library.experts], axis=1)
        st.router_params, st.router_opt, rl = router_step(
            st.router_params, st.router_opt, toks, jnp.asarray(losses))
        routed_loss = float(losses[np.arange(len(choice)), choice].mean())
        best_loss = float(losses.min(axis=1).mean())
        st.history.append({"step": step_i, "router_loss": float(rl),
                           "routed_loss": routed_loss,
                           "oracle_loss": best_loss})
        if verbose and step_i % 10 == 0:
            print(f"  e2e step {step_i}: router {float(rl):.4f} "
                  f"routed {routed_loss:.3f} oracle {best_loss:.3f}",
                  flush=True)
    return st
