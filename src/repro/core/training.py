"""Router training (paper eq. 2/3), expert pre-training, and the online
adaptation step that keeps a deployed router tracking expert drift.

Paper recipe, reproduced: ADAM, weight decay 1e-5, lr 5e-5 with
exponential decay 0.9, inputs curtailed to a fixed token budget, early
stopping with patience conditioned on validation loss measured 4x per
epoch, checkpointing of the best validation model.

Online adaptation (``make_router_update_step``): the paper's router
"continually tracks downstream expert performance"; serving feedback
(observed masked NLL of the chosen expert, ``serving.feedback``) is
replayed through a jit'd incremental SGD/EMA step on shadow weights,
published atomically via ``core.router.VersionedParams.swap``.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.library import ExpertSpec, ModelLibrary
from repro.core.router import (RouterConfig, add_uncertainty_head,
                               losses_from_emb, predict_losses,
                               router_embed, uncertainty_from_emb)
from repro.data.batching import BatchIterator
from repro.data.corpus import DomainCorpus
from repro.models.model import count_params, init_model, lm_loss
from repro.optim import adamw_init, adamw_update, exp_decay_schedule


@dataclasses.dataclass
class TrainLog:
    steps: list = dataclasses.field(default_factory=list)
    train_loss: list = dataclasses.field(default_factory=list)
    val_loss: list = dataclasses.field(default_factory=list)
    best_val: float = float("inf")
    best_step: int = -1
    stopped_early: bool = False


# ----------------------------------------------------------- experts

def train_expert(spec: ExpertSpec, corpus: DomainCorpus, *, steps=300,
                 batch=16, seq=128, lr=1e-3, seed=0, log_every=100,
                 verbose=False) -> ExpertSpec:
    """MLM-train one expert on its domain mixture."""
    key = jax.random.PRNGKey(seed)
    params, _ = init_model(key, spec.cfg)
    opt = adamw_init(params)
    it = BatchIterator(corpus, spec.train_mixture, batch, seq, seed=seed + 1)

    @jax.jit
    def step_fn(p, o, b):
        (loss, _), g = jax.value_and_grad(
            lambda pp: lm_loss(pp, spec.cfg, b, remat=False), has_aux=True)(p)
        p2, o2 = adamw_update(p, g, o, lr=lr, weight_decay=1e-5)
        return p2, o2, loss

    for i in range(steps):
        b = next(it)
        jb = {k: jnp.asarray(v) for k, v in b.items() if k != "domain"}
        params, opt, loss = step_fn(params, opt, jb)
        if verbose and (i % log_every == 0 or i == steps - 1):
            print(f"    {spec.name} step {i} loss {float(loss):.3f}", flush=True)
    spec.params = params
    spec.n_params = count_params(params)
    return spec


def train_library(library: ModelLibrary, corpus: DomainCorpus, *, steps=300,
                  batch=16, seq=128, seed=0, verbose=True) -> ModelLibrary:
    for i, e in enumerate(library.experts):
        t0 = time.time()
        train_expert(e, corpus, steps=steps, batch=batch, seq=seq,
                     seed=seed + i, verbose=False)
        if verbose:
            print(f"  trained {e.name}: {e.n_params:,d} params "
                  f"({time.time()-t0:.0f}s)", flush=True)
    return library


# ------------------------------------------------------------ router

def router_loss(params, rc: RouterConfig, batch, target_losses,
                divergence="mse", unc_weight: float = 0.5):
    """Divergence D(R(z;W) || L(z, M_i)) summed over the library (eq. 2).

    When ``params`` carries an uncertainty head (``"unc"``), a residual-
    regression term trains it alongside loss prediction: sigma chases
    ``stop_grad(|L-hat - L|)``, so the head learns to predict how wrong
    the loss head is without perturbing the loss head's own gradients —
    checkpoints without the head train exactly as before.
    """
    emb = router_embed(params, rc, batch)
    pred = losses_from_emb(params["head"], emb)
    t = jnp.asarray(target_losses, jnp.float32)
    if divergence == "mse":
        loss = jnp.mean(jnp.square(pred - t))
    elif divergence == "huber":
        d = jnp.abs(pred - t)
        loss = jnp.mean(jnp.where(d < 1.0, 0.5 * d * d, d - 0.5))
    else:
        raise ValueError(divergence)
    if "unc" in params and unc_weight:
        resid = jax.lax.stop_gradient(jnp.abs(pred - t))
        sigma = uncertainty_from_emb(params["unc"],
                                     jax.lax.stop_gradient(emb))
        loss = loss + unc_weight * jnp.mean(jnp.square(sigma - resid))
    return loss


def calibrate_uncertainty(router_params, rc: RouterConfig, tokens,
                          target_losses, *, steps=300, batch=64, lr=3e-3,
                          seed=0, verbose=False) -> dict:
    """Retrofit + train an uncertainty head on a frozen router.

    For checkpoints trained before the cascade existed: attaches a fresh
    ``"unc"`` head (``router.add_uncertainty_head``) and regresses it
    onto the frozen router's actual absolute residuals
    ``|L-hat(z) - L(z, M_i)|`` over a held-out (tokens, loss) table.
    Embeddings and residuals are precomputed once, so calibration is a
    few hundred head-only MLP steps regardless of encoder size.  Returns
    a params copy; encoder and loss head are untouched (shared by
    reference), so routing decisions are bit-identical.
    """
    if "unc" not in router_params:
        router_params = add_uncertainty_head(
            jax.random.PRNGKey(seed + 17), router_params, rc)

    # precompute pooled embeddings + residual targets, in chunks
    embed = jax.jit(lambda t: router_embed(router_params, rc, {"tokens": t}))
    score = jax.jit(lambda t: predict_losses(router_params, rc, {"tokens": t}))
    B = 256
    embs, preds = [], []
    for i in range(0, len(tokens), B):
        chunk = jnp.asarray(tokens[i:i + B])
        embs.append(np.asarray(embed(chunk)))
        preds.append(np.asarray(score(chunk)))
    emb = np.concatenate(embs)
    resid = np.abs(np.concatenate(preds)
                   - np.asarray(target_losses, np.float32))

    unc = router_params["unc"]
    opt = adamw_init(unc)

    @jax.jit
    def step_fn(u, o, e, r):
        l, g = jax.value_and_grad(lambda uu: jnp.mean(jnp.square(
            uncertainty_from_emb(uu, e) - r)))(u)
        u2, o2 = adamw_update(u, g, o, lr=lr, weight_decay=1e-5)
        return u2, o2, l

    rng = np.random.default_rng(seed)
    for s in range(steps):
        idx = rng.integers(0, len(emb), size=min(batch, len(emb)))
        unc, opt, l = step_fn(unc, opt, jnp.asarray(emb[idx]),
                              jnp.asarray(resid[idx]))
        if verbose and s % 100 == 0:
            print(f"  calibrate_uncertainty step {s} loss {float(l):.4f}",
                  flush=True)
    out = dict(router_params)
    out["unc"] = unc
    return out


# ------------------------------------------------- online adaptation

def router_prediction_error(params, rc: RouterConfig, toks, expert_idx,
                            observed):
    """Mean |L-hat[chosen] - L_observed| over a feedback batch — the
    adaptation loop's before/after health metric (jit-friendly)."""
    pred = predict_losses(params, rc, {"tokens": toks})
    sel = jnp.take_along_axis(
        pred, jnp.asarray(expert_idx, jnp.int32)[:, None], axis=1)[:, 0]
    return jnp.mean(jnp.abs(sel - jnp.asarray(observed, jnp.float32)))


def make_router_update_step(rc: RouterConfig, *, lr: float = 1e-2,
                            ema: float = 0.0, trainable: str = "all"):
    """Build the jit'd incremental update for online router adaptation.

    The returned ``step(params, toks, expert_idx, observed)`` performs
    one SGD step on the *bandit* regression loss

        mean_i (L-hat(z_i)[a_i] - L_obs(z_i, a_i))^2

    where ``a_i`` is the expert that actually served prompt ``z_i`` and
    ``L_obs`` its measured masked NLL (``serving.feedback``) — only the
    chosen expert's prediction is supervised, exactly the signal live
    traffic provides.  It returns ``(new_params, loss)``; the input tree
    is never mutated (shadow weights): the caller publishes the result
    atomically via ``core.router.VersionedParams.swap``.

    ``ema`` in [0, 1) blends the step back toward the current weights
    (``new = ema * old + (1 - ema) * sgd``) — a trust region that damps
    noisy single-batch gradients; 0 is plain SGD.  ``trainable`` picks
    the update scope: ``"all"`` adapts encoder + loss head, ``"head"``
    freezes the encoder and adapts the loss head only (cheaper and far
    less able to distort off-distribution predictions — the default
    serving choice).  The uncertainty head, if present, is never
    touched: sigma stays calibrated to the *training-time* residual
    scale and escalation behaviour remains stable under adaptation.
    """
    assert 0.0 <= ema < 1.0 and trainable in ("all", "head")

    def _sgd(p, g):
        new = jax.tree.map(lambda w, gw: w - lr * gw, p, g)
        if ema:
            new = jax.tree.map(lambda w, nw: ema * w + (1.0 - ema) * nw,
                               p, new)
        return new

    @jax.jit
    def step(params, toks, expert_idx, observed):
        observed = jnp.asarray(observed, jnp.float32)
        idx = jnp.asarray(expert_idx, jnp.int32)[:, None]

        def bandit_loss(p):
            pred = predict_losses(p, rc, {"tokens": toks})
            sel = jnp.take_along_axis(pred, idx, axis=1)[:, 0]
            return jnp.mean(jnp.square(sel - observed))

        if trainable == "head":
            def head_loss(head):
                return bandit_loss({**params, "head": head})

            l, g = jax.value_and_grad(head_loss)(params["head"])
            return {**params, "head": _sgd(params["head"], g)}, l

        frozen = {k: v for k, v in params.items()
                  if k not in ("encoder", "head")}

        def live_loss(live):
            return bandit_loss({**frozen, **live})

        live = {"encoder": params["encoder"], "head": params["head"]}
        l, g = jax.value_and_grad(live_loss)(live)
        return {**frozen, **_sgd(live, g)}, l

    return step


def train_router(router_params, rc: RouterConfig, train_data, val_data, *,
                 epochs=8, batch=32, lr=5e-5, lr_decay=0.9, patience=16,
                 weight_decay=1e-5, seed=0, divergence="mse",
                 verbose=True) -> tuple[dict, TrainLog]:
    """Supervised router training with the paper's recipe.

    train_data/val_data: dicts {"tokens": (N,S), "loss": (N, n_models)}.
    lr decays exponentially by ``lr_decay`` per epoch; validation is
    measured 4x per epoch; early stopping patience in validation checks.
    """
    N = train_data["tokens"].shape[0]
    steps_per_epoch = max(N // batch, 1)
    schedule = exp_decay_schedule(lr, lr_decay, steps_per_epoch)
    opt = adamw_init(router_params)
    rng = np.random.default_rng(seed)
    log = TrainLog()
    best_params = router_params
    val_every = max(steps_per_epoch // 4, 1)
    bad = 0

    @jax.jit
    def step_fn(p, o, toks, targets):
        l, g = jax.value_and_grad(
            lambda pp: router_loss(pp, rc, {"tokens": toks}, targets,
                                   divergence))(p)
        p2, o2 = adamw_update(p, g, o, lr=schedule,
                              weight_decay=weight_decay)
        return p2, o2, l

    @jax.jit
    def val_fn(p):
        return router_loss(p, rc, {"tokens": jnp.asarray(val_data["tokens"])},
                           val_data["loss"], divergence)

    step = 0
    for ep in range(epochs):
        perm = rng.permutation(N)
        for s in range(steps_per_epoch):
            idx = perm[s * batch:(s + 1) * batch]
            router_params, opt, l = step_fn(
                router_params, opt, jnp.asarray(train_data["tokens"][idx]),
                jnp.asarray(train_data["loss"][idx]))
            step += 1
            if step % val_every == 0:
                vl = float(val_fn(router_params))
                log.steps.append(step)
                log.train_loss.append(float(l))
                log.val_loss.append(vl)
                if vl < log.best_val - 1e-5:
                    log.best_val, log.best_step = vl, step
                    best_params = jax.tree.map(lambda x: x, router_params)
                    bad = 0
                else:
                    bad += 1
                if bad >= patience:
                    log.stopped_early = True
                    if verbose:
                        print(f"  early stop at step {step} "
                              f"(best val {log.best_val:.4f})", flush=True)
                    return best_params, log
        if verbose:
            print(f"  epoch {ep}: train {float(l):.4f} "
                  f"val {log.val_loss[-1] if log.val_loss else float('nan'):.4f}",
                  flush=True)
    return best_params, log
