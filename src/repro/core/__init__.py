"""Tryage core: the paper's contribution — a perceptive router that
predicts per-prompt expert losses and routes under constraint objectives."""

from repro.core.library import ExpertSpec, ModelLibrary, paper_library_specs
from repro.core.objective import (Constraint, size_constraint,
                                  recency_constraint, routing_scores, route)
from repro.core.router import (RouterConfig, init_router, predict_losses,
                               router_embed)
from repro.core.qtable import build_q_table, mlm_accuracy
from repro.core.training import TrainLog, train_router
from repro.core.pareto import pareto_sweep

__all__ = [
    "ExpertSpec", "ModelLibrary", "paper_library_specs", "Constraint",
    "size_constraint", "recency_constraint", "routing_scores", "route",
    "RouterConfig", "init_router", "predict_losses", "router_embed",
    "build_q_table", "mlm_accuracy", "TrainLog", "train_router",
    "pareto_sweep",
]
