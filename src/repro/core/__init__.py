"""Tryage core: the paper's contribution — a perceptive router that
predicts per-prompt expert losses and routes under constraint objectives."""

from repro.core.library import ExpertSpec, ModelLibrary, paper_library_specs
from repro.core.objective import (Constraint, size_constraint,
                                  recency_constraint, routing_scores, route)
from repro.core.router import (RouterConfig, VersionedParams, init_router,
                               predict_losses, router_embed)
from repro.core.qtable import build_q_table, mlm_accuracy
from repro.core.training import (TrainLog, make_router_update_step,
                                 router_prediction_error, train_router)
from repro.core.pareto import pareto_sweep

__all__ = [
    "ExpertSpec", "ModelLibrary", "paper_library_specs", "Constraint",
    "size_constraint", "recency_constraint", "routing_scores", "route",
    "RouterConfig", "VersionedParams", "init_router", "predict_losses",
    "router_embed", "build_q_table", "mlm_accuracy", "TrainLog",
    "make_router_update_step", "router_prediction_error", "train_router",
    "pareto_sweep",
]
