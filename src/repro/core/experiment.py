"""End-to-end Tryage experiment pipeline.

Produces every quantity the paper reports, with artifacts cached under
``experiments/tryage/`` so individual benchmarks can re-read them:

  1. train the 11-expert library on the synthetic Pile (Fig. 2 premise)
  2. build ground-truth Q-tables (per-prompt loss/accuracy per expert)
  3. train the perceptive router on the train Q-table (eq. 2/3)
  4. evaluate: eps loss-prediction error, optimal-selection accuracy vs
     baselines (Fig. 3a), allocation matrix (3b), per-domain accuracy
     (3c/d), latent separation (Fig. 4), Pareto sweep (Fig. 5)
"""

from __future__ import annotations

import dataclasses
import json
import os
import pickle
import time

import jax
import numpy as np

from repro.core import baselines as bl
from repro.core.library import ModelLibrary, paper_library_specs
from repro.core.objective import size_constraint
from repro.core.pareto import pareto_sweep
from repro.core.qtable import build_q_table, mlm_accuracy
from repro.core.router import RouterConfig, init_router, predict_losses, router_embed
from repro.core.training import train_library, train_router
from repro.data.batching import mlm_batch
from repro.data.corpus import DOMAINS, DomainCorpus

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "tryage")


@dataclasses.dataclass
class ExperimentConfig:
    vocab: int = 512
    seq: int = 128
    expert_steps: int = 300
    n_train_prompts: int = 3072
    n_val_prompts: int = 384
    n_test_per_domain: int = 96
    router_epochs: int = 10
    router_batch: int = 32
    seed: int = 0


def _eval_batches(corpus, weights, n, seq, seed, batch=64):
    """n prompts as a list of MLM batches with domain labels."""
    rng = np.random.default_rng(seed)
    out = []
    done = 0
    while done < n:
        b = min(batch, n - done)
        toks, labels = corpus.sample_mixture(weights, b, seq, rng)
        mb = mlm_batch(toks, rng, 0.15, corpus.vocab_size)
        mb["domain"] = labels
        out.append(mb)
        done += b
    return out


def _silhouette(X: np.ndarray, labels: np.ndarray) -> float:
    """Mean silhouette coefficient (quantitative Fig.-4 stand-in)."""
    X = X / (np.linalg.norm(X, axis=1, keepdims=True) + 1e-9)
    D = np.sqrt(np.maximum(
        (X ** 2).sum(1)[:, None] + (X ** 2).sum(1)[None, :]
        - 2 * X @ X.T, 0.0))
    uniq = np.unique(labels)
    s = np.zeros(len(X))
    for i in range(len(X)):
        same = labels == labels[i]
        same[i] = False
        a = D[i, same].mean() if same.any() else 0.0
        b = min(D[i, labels == u].mean() for u in uniq if u != labels[i])
        s[i] = (b - a) / max(a, b, 1e-9)
    return float(s.mean())


def run_experiment(xc: ExperimentConfig = ExperimentConfig(),
                   verbose=True, save=True) -> dict:
    t0 = time.time()
    corpus = DomainCorpus(vocab_size=xc.vocab, seed=xc.seed)
    uniform = {d: 1.0 / len(DOMAINS) for d in DOMAINS}

    # 1. expert library -------------------------------------------------
    library = ModelLibrary(paper_library_specs(vocab=xc.vocab))
    if verbose:
        print(f"[{time.time()-t0:6.0f}s] training {len(library)} experts "
              f"({xc.expert_steps} steps each)", flush=True)
    train_library(library, corpus, steps=xc.expert_steps, seq=xc.seq,
                  seed=xc.seed, verbose=verbose)

    # 2. Q-tables --------------------------------------------------------
    if verbose:
        print(f"[{time.time()-t0:6.0f}s] building Q-tables", flush=True)
    train_b = _eval_batches(corpus, uniform, xc.n_train_prompts, xc.seq,
                            xc.seed + 101)
    val_b = _eval_batches(corpus, uniform, xc.n_val_prompts, xc.seq,
                          xc.seed + 202)
    # test: balanced per-domain for per-domain metrics
    test_b = []
    for di, d in enumerate(DOMAINS):
        test_b += _eval_batches(corpus, {d: 1.0}, xc.n_test_per_domain,
                                xc.seq, xc.seed + 303 + di)
    q_train = build_q_table(library, train_b, progress=verbose)
    q_val = build_q_table(library, val_b)
    q_test = build_q_table(library, test_b)

    cat = lambda bs, k: np.concatenate([b[k] for b in bs])
    train_data = {"tokens": cat(train_b, "tokens"), "loss": q_train["loss"]}
    val_data = {"tokens": cat(val_b, "tokens"), "loss": q_val["loss"]}
    test_tokens = cat(test_b, "tokens")

    # 3. router ----------------------------------------------------------
    if verbose:
        print(f"[{time.time()-t0:6.0f}s] training router", flush=True)
    rc = RouterConfig(n_models=len(library), vocab_size=xc.vocab)
    rp, _ = init_router(jax.random.PRNGKey(xc.seed + 7), rc)
    rp, log = train_router(rp, rc, train_data, val_data,
                           epochs=xc.router_epochs, batch=xc.router_batch,
                           verbose=verbose)

    # 4. evaluation -------------------------------------------------------
    if verbose:
        print(f"[{time.time()-t0:6.0f}s] evaluating", flush=True)
    pred_chunks = []
    B = 256
    score = jax.jit(lambda toks: predict_losses(rp, rc, {"tokens": toks}))
    for i in range(0, len(test_tokens), B):
        pred_chunks.append(np.asarray(score(test_tokens[i:i + B])))
    pred = np.concatenate(pred_chunks)                     # (N, M)

    eps = float(np.mean(np.abs(pred - q_test["loss"])))
    tryage_choice = pred.argmin(axis=1)
    N = len(test_tokens)

    choices = {
        "tryage": tryage_choice,
        "oracle": bl.oracle_choices(q_test),
        "random": bl.random_router(N, len(library), xc.seed),
        "largest": bl.largest_router(library, N),
        "leaderboard": bl.leaderboard_router(q_train, N),
        "keyword (gorilla-class)": bl.keyword_router(
            test_tokens, corpus, library),
    }
    sel_acc = {k: bl.selection_accuracy(v, q_test) for k, v in choices.items()}
    agg_acc = {k: mlm_accuracy(q_test, v) for k, v in choices.items()}

    # per-domain accuracy: tryage vs each expert (Fig. 3c/d)
    per_domain = {}
    doms = q_test["domain"]
    for di, d in enumerate(DOMAINS):
        m = doms == di
        row = {e.name: float(q_test["acc"][m, mi].mean())
               for mi, e in enumerate(library.experts)}
        idx = np.where(m)[0]
        row["tryage"] = float(q_test["acc"][idx, tryage_choice[idx]].mean())
        per_domain[d] = row

    # allocation matrix (Fig. 3b)
    alloc = np.zeros((len(DOMAINS), len(library)))
    for di in range(len(DOMAINS)):
        m = doms == di
        for mi in range(len(library)):
            alloc[di, mi] = float((tryage_choice[m] == mi).mean())

    # latent separation (Fig. 4)
    embed = jax.jit(lambda toks: router_embed(rp, rc, {"tokens": toks}))
    embs = np.concatenate([np.asarray(embed(test_tokens[i:i + B]))
                           for i in range(0, N, B)])
    rp0, _ = init_router(jax.random.PRNGKey(xc.seed + 99), rc)
    embed0 = jax.jit(lambda toks: router_embed(rp0, rc, {"tokens": toks}))
    embs0 = np.concatenate([np.asarray(embed0(test_tokens[i:i + B]))
                            for i in range(0, N, B)])
    # generalist-expert embedding (GPT-2-analog comparison point)
    gen = library.experts[0]
    from repro.models.model import encode as enc_fn
    gen_embed = jax.jit(lambda toks: enc_fn(
        gen.params, gen.cfg, {"tokens": toks}).mean(axis=1))
    embs_gen = np.concatenate([np.asarray(gen_embed(test_tokens[i:i + B]))
                               for i in range(0, N, B)])
    sil = {"tryage_router": _silhouette(embs, doms),
           "untrained_router": _silhouette(embs0, doms),
           "generalist_lm": _silhouette(embs_gen, doms)}

    # Pareto sweep (Fig. 5)
    pareto = pareto_sweep(pred, q_test, library, size_constraint(library))

    results = {
        "config": dataclasses.asdict(xc),
        "library": [{"name": e.name, "n_params": e.n_params,
                     "recency": e.recency} for e in library.experts],
        "router_eps": eps,
        "router_val_best": log.best_val,
        "router_stopped_early": log.stopped_early,
        "selection_accuracy": sel_acc,
        "aggregate_accuracy": agg_acc,
        "per_domain": per_domain,
        "allocation": alloc.tolist(),
        "silhouette": sil,
        "pareto": pareto,
        "wall_s": round(time.time() - t0, 1),
    }

    if save:
        os.makedirs(ART_DIR, exist_ok=True)
        with open(os.path.join(ART_DIR, "results.json"), "w") as f:
            json.dump(results, f, indent=1)
        with open(os.path.join(ART_DIR, "artifacts.pkl"), "wb") as f:
            pickle.dump({
                "library": library, "router_params": rp, "rc": rc,
                "q_test": q_test, "q_train": q_train, "pred": pred,
                "test_tokens": test_tokens, "corpus": corpus,
                "train_log": dataclasses.asdict(log),
            }, f)
        if verbose:
            print(f"saved artifacts to {ART_DIR}", flush=True)
    return results


def load_artifacts():
    with open(os.path.join(ART_DIR, "artifacts.pkl"), "rb") as f:
        return pickle.load(f)


def load_results():
    with open(os.path.join(ART_DIR, "results.json")) as f:
        return json.load(f)


if __name__ == "__main__":
    import sys
    fast = "--fast" in sys.argv
    xc = ExperimentConfig()
    if fast:
        xc = ExperimentConfig(expert_steps=60, n_train_prompts=512,
                              n_val_prompts=128, n_test_per_domain=24,
                              router_epochs=3)
    res = run_experiment(xc)
    print(json.dumps({k: v for k, v in res.items()
                      if k in ("router_eps", "selection_accuracy",
                               "aggregate_accuracy", "silhouette",
                               "wall_s")}, indent=1))
