"""Q-table construction: ground-truth per-prompt expert losses.

The Oracle router (paper eq. 1) needs L(z, M_i) for every prompt z and
expert M_i.  We compute per-prompt masked-LM loss and masked-token top-1
accuracy by running each expert over the evaluation prompts.  This is the
supervision signal for the predictive router (eq. 2) and the evaluation
target for routing accuracy (paper Fig. 3a).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.library import ModelLibrary
from repro.models.model import forward


def _per_prompt_metrics(params, cfg, batch):
    """Returns (loss (B,), acc (B,)) for an MLM batch."""
    logits, _, _ = forward(params, cfg, batch, mode="train", remat=False)
    logits = logits.astype(jnp.float32)
    targets, mask = batch["targets"], batch["mask"].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(-1), 1.0)
    loss = nll.sum(-1) / denom
    pred = jnp.argmax(logits, axis=-1)
    acc = ((pred == targets).astype(jnp.float32) * mask).sum(-1) / denom
    return loss, acc


@functools.partial(jax.jit, static_argnames=("cfg",))
def _per_prompt_metrics_jit(params, cfg, batch):
    return _per_prompt_metrics(params, cfg, batch)


def build_q_table(library: ModelLibrary, batches: list[dict],
                  progress: bool = False):
    """Run every expert over every batch of prompts.

    batches: list of MLM batches (each {"tokens","targets","mask"}).
    Returns dict with:
      loss (N, n_models), acc (N, n_models), domain (N,)
    """
    losses, accs = [], []
    domains = np.concatenate([b["domain"] for b in batches])
    for e in library.experts:
        el, ea = [], []
        for b in batches:
            jb = {k: jnp.asarray(v) for k, v in b.items() if k != "domain"}
            l, a = _per_prompt_metrics_jit(e.params, e.cfg, jb)
            el.append(np.asarray(l))
            ea.append(np.asarray(a))
        losses.append(np.concatenate(el))
        accs.append(np.concatenate(ea))
        if progress:
            print(f"  qtable: {e.name} mean_loss={np.mean(losses[-1]):.3f} "
                  f"mean_acc={np.mean(accs[-1]):.3f}", flush=True)
    return {
        "loss": np.stack(losses, axis=1),
        "acc": np.stack(accs, axis=1),
        "domain": domains,
    }


def mlm_accuracy(qtable: dict, choices: np.ndarray) -> float:
    """Aggregate MLM accuracy achieved by a routing policy ``choices``."""
    return float(np.mean(qtable["acc"][np.arange(len(choices)), choices]))
