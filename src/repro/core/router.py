"""The perceptive router R(z, M_i; W).

A small encoder LM (BERT-tiny/small scale, per the paper: "we achieved
favorable loss prediction accuracy with Bert-tiny... we selected
BERT-small since larger models did not yield better performance") with a
regression head producing an |M|-dimensional vector of predicted
downstream losses — the learned Q function over routing actions.

The router also exposes its pooled embedding (``router_embed``) for the
latent-separation analysis of paper Fig. 4.

Confidence-aware extension (cascade routing): an optional *uncertainty
head* — a second MLP over the same pooled embedding — predicts the
per-expert absolute residual |L-hat - L| of the loss head, i.e. how far
off the router expects its own prediction to be.  ``sigma`` feeds the
calibrated confidence score in ``core.objective`` and the serving
engine's escalation rule.  Checkpoints trained before this head exists
keep working: every consumer falls back to a constant prior
(``sigma = 1``) when ``params`` has no ``"unc"`` entry.

Online adaptation: a serving engine that continually refreshes the
router (``core.training.make_router_update_step`` over replayed
feedback)
must never let a half-updated parameter tree reach an in-flight scoring
call, and must be able to tell *which* parameter snapshot produced any
memoised decision.  ``VersionedParams`` is that contract: an immutable
(params, version) pair whose ``swap`` returns a new snapshot with a
monotonically increasing version.  Scoring functions take the params
tree as an argument, so publishing an update is a single reference
assignment — readers see either the old complete tree or the new one —
and the version is threaded into the decision-cache key so verdicts
scored by a superseded router can never be served again.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.common import AttnConfig, ModelConfig
from repro.models.layers import _init
from repro.models.model import encode, init_model


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    n_models: int
    vocab_size: int = 512
    num_layers: int = 4           # BERT-small scale
    d_model: int = 128
    num_heads: int = 4
    d_ff: int = 512
    head_hidden: int = 128

    def encoder_config(self) -> ModelConfig:
        return ModelConfig(
            name="tryage-router", family="dense",
            num_layers=self.num_layers, d_model=self.d_model,
            num_heads=self.num_heads, num_kv_heads=self.num_heads,
            d_ff=self.d_ff, vocab_size=self.vocab_size,
            attn=AttnConfig(rope_theta=10000.0, causal=False),
            layer_pattern=("attn",), moe_pattern=(False,),
            is_encoder=True, tie_embeddings=True, norm_kind="layernorm",
            act="gelu", dtype="float32")


@dataclasses.dataclass(frozen=True)
class VersionedParams:
    """Immutable router-parameter snapshot with a monotone version.

    The serving engine scores against ``params`` by value (jit arguments,
    not captured state), so an online update is published atomically by
    replacing the whole snapshot: ``swap`` never mutates, it returns a
    fresh snapshot with ``version + 1``.  The version participates in
    the router-decision cache key (``serving.cache.DecisionCache.key``):
    bumping it makes every verdict scored by the previous parameters
    unreachable, which is exactly the invalidation the adaptation loop
    needs."""

    params: dict
    version: int = 0

    def swap(self, new_params: dict) -> "VersionedParams":
        """Publish ``new_params`` as the next snapshot (version + 1)."""
        return VersionedParams(new_params, self.version + 1)


# softplus floor on predicted residuals: keeps sigma > 0 so confidence
# 1/(1+sigma) stays strictly below 1 and escalation thresholds behave.
UNC_FLOOR = 1e-3

_HEAD_LOGICAL = {"w1": ("embed", "mlp"), "b1": ("mlp",),
                 "w2": ("mlp", "vocab"), "b2": ("vocab",)}


def _init_mlp_head(key, rc: RouterConfig):
    k1, k2 = jax.random.split(key)
    d, hh = rc.d_model, rc.head_hidden
    return {
        "w1": _init(k1, (d, hh), 1 / math.sqrt(d), jnp.float32),
        "b1": jnp.zeros((hh,), jnp.float32),
        "w2": _init(k2, (hh, rc.n_models), 1 / math.sqrt(hh), jnp.float32),
        "b2": jnp.zeros((rc.n_models,), jnp.float32),
    }


def init_router(key, rc: RouterConfig, uncertainty: bool = False):
    k_enc, k_head, k_unc = jax.random.split(key, 3)
    enc_cfg = rc.encoder_config()
    enc_params, enc_logical = init_model(k_enc, enc_cfg)
    params = {
        "encoder": enc_params,
        "head": _init_mlp_head(k_head, rc),
    }
    logical = {
        "encoder": enc_logical,
        "head": dict(_HEAD_LOGICAL),
    }
    if uncertainty:
        params["unc"] = _init_mlp_head(k_unc, rc)
        logical["unc"] = dict(_HEAD_LOGICAL)
    return params, logical


def add_uncertainty_head(key, params: dict, rc: RouterConfig) -> dict:
    """Retrofit an uncertainty head onto a pre-cascade checkpoint.

    Returns a shallow copy of ``params`` with a fresh ``"unc"`` head;
    encoder and loss head are shared by reference, so the loss
    predictions of the returned params are bit-identical."""
    out = dict(params)
    out["unc"] = _init_mlp_head(key, rc)
    return out


def _pool(hidden, tokens):
    """Mean-pool over non-pad positions. hidden (B,S,d), tokens (B,S)."""
    valid = (tokens != 0).astype(hidden.dtype)[..., None]
    return (hidden * valid).sum(1) / jnp.maximum(valid.sum(1), 1.0)


def router_embed(params, rc: RouterConfig, batch, use_kernel=False):
    """Pooled prompt embedding (B, d)."""
    hidden = encode(params["encoder"], rc.encoder_config(), batch)
    return _pool(hidden, batch["tokens"])


def predict_losses(params, rc: RouterConfig, batch, use_kernel=False,
                   interpret=None):
    """Predicted per-expert losses L-hat (B, n_models), in log-loss units.

    softplus keeps predictions positive (losses are non-negative), which
    stabilizes early training against the MSE divergence.  ``interpret``
    follows the kernel convention: None = compiled on TPU/GPU, interpret
    on CPU.
    """
    emb = router_embed(params, rc, batch)
    if use_kernel:
        from repro.kernels.router_score import ops as rs_ops
        return rs_ops.router_head(emb, params["head"], interpret=interpret)
    return losses_from_emb(params["head"], emb)


def losses_from_emb(head_params, emb):
    """L-hat (B, n_models) from a precomputed pooled embedding — the
    single definition of the loss head's math (XLA path); training
    reuses it so the trained function is exactly the served one."""
    h = jax.nn.gelu(emb @ head_params["w1"] + head_params["b1"])
    raw = h @ head_params["w2"] + head_params["b2"]
    return jax.nn.softplus(raw)


def uncertainty_from_emb(unc_params, emb):
    """sigma (B, n_models): predicted |L-hat - L| residual magnitude,
    strictly positive.  Runs on a precomputed pooled embedding so the
    serving engine can reuse the encoder pass of the decision path."""
    h = jax.nn.gelu(emb @ unc_params["w1"] + unc_params["b1"])
    raw = h @ unc_params["w2"] + unc_params["b2"]
    return jax.nn.softplus(raw) + UNC_FLOOR


def predict_uncertainty(params, rc: RouterConfig, batch):
    """Per-expert predictive uncertainty sigma (B, n_models).

    Falls back to the constant prior sigma = 1 when ``params`` carries no
    uncertainty head (pre-cascade checkpoints): every expert is equally
    untrusted, so confidence is flat and thresholds act globally."""
    emb = router_embed(params, rc, batch)
    if "unc" not in params:
        return jnp.ones((emb.shape[0], rc.n_models), jnp.float32)
    return uncertainty_from_emb(params["unc"], emb)
