"""The perceptive router R(z, M_i; W).

A small encoder LM (BERT-tiny/small scale, per the paper: "we achieved
favorable loss prediction accuracy with Bert-tiny... we selected
BERT-small since larger models did not yield better performance") with a
regression head producing an |M|-dimensional vector of predicted
downstream losses — the learned Q function over routing actions.

The router also exposes its pooled embedding (``router_embed``) for the
latent-separation analysis of paper Fig. 4.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.common import AttnConfig, ModelConfig
from repro.models.layers import _init
from repro.models.model import encode, init_model


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    n_models: int
    vocab_size: int = 512
    num_layers: int = 4           # BERT-small scale
    d_model: int = 128
    num_heads: int = 4
    d_ff: int = 512
    head_hidden: int = 128

    def encoder_config(self) -> ModelConfig:
        return ModelConfig(
            name="tryage-router", family="dense",
            num_layers=self.num_layers, d_model=self.d_model,
            num_heads=self.num_heads, num_kv_heads=self.num_heads,
            d_ff=self.d_ff, vocab_size=self.vocab_size,
            attn=AttnConfig(rope_theta=10000.0, causal=False),
            layer_pattern=("attn",), moe_pattern=(False,),
            is_encoder=True, tie_embeddings=True, norm_kind="layernorm",
            act="gelu", dtype="float32")


def init_router(key, rc: RouterConfig):
    k_enc, k_h1, k_h2 = jax.random.split(key, 3)
    enc_cfg = rc.encoder_config()
    enc_params, enc_logical = init_model(k_enc, enc_cfg)
    d, hh = rc.d_model, rc.head_hidden
    params = {
        "encoder": enc_params,
        "head": {
            "w1": _init(k_h1, (d, hh), 1 / math.sqrt(d), jnp.float32),
            "b1": jnp.zeros((hh,), jnp.float32),
            "w2": _init(k_h2, (hh, rc.n_models), 1 / math.sqrt(hh), jnp.float32),
            "b2": jnp.zeros((rc.n_models,), jnp.float32),
        },
    }
    logical = {
        "encoder": enc_logical,
        "head": {"w1": ("embed", "mlp"), "b1": ("mlp",),
                 "w2": ("mlp", "vocab"), "b2": ("vocab",)},
    }
    return params, logical


def _pool(hidden, tokens):
    """Mean-pool over non-pad positions. hidden (B,S,d), tokens (B,S)."""
    valid = (tokens != 0).astype(hidden.dtype)[..., None]
    return (hidden * valid).sum(1) / jnp.maximum(valid.sum(1), 1.0)


def router_embed(params, rc: RouterConfig, batch, use_kernel=False):
    """Pooled prompt embedding (B, d)."""
    hidden = encode(params["encoder"], rc.encoder_config(), batch)
    return _pool(hidden, batch["tokens"])


def predict_losses(params, rc: RouterConfig, batch, use_kernel=False,
                   interpret=None):
    """Predicted per-expert losses L-hat (B, n_models), in log-loss units.

    softplus keeps predictions positive (losses are non-negative), which
    stabilizes early training against the MSE divergence.  ``interpret``
    follows the kernel convention: None = compiled on TPU/GPU, interpret
    on CPU.
    """
    emb = router_embed(params, rc, batch)
    if use_kernel:
        from repro.kernels.router_score import ops as rs_ops
        return rs_ops.router_head(emb, params["head"], interpret=interpret)
    h = jax.nn.gelu(emb @ params["head"]["w1"] + params["head"]["b1"])
    raw = h @ params["head"]["w2"] + params["head"]["b2"]
    return jax.nn.softplus(raw)
