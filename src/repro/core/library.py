"""The expert model library M = (M_1 ... M_n).

The paper's library is 11 HuggingFace BERT-family checkpoints (RoBERTa,
bert-base/small/tiny variants, CodeBERT, PatentBERT, ClinicalBERT,
FinancialBERT, SECBert, ...).  Offline we build the analogous library from
our own substrate: encoder LMs of varying size, each trained on a domain-
biased mixture of the synthetic Pile (see data/corpus.py) so the library
exhibits the paper's Fig.-2 premise — a generalist with the best mean
accuracy plus specialists that beat it on their home domains.

ExpertSpec carries the static metadata the routing constraints consume
(param count, recency, family) — the model-card analogue.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.data.corpus import DOMAINS
from repro.models.common import AttnConfig, ModelConfig


def _enc(name, layers, d, heads, dff, vocab) -> ModelConfig:
    return ModelConfig(
        name=name, family="dense", num_layers=layers, d_model=d,
        num_heads=heads, num_kv_heads=heads, d_ff=dff, vocab_size=vocab,
        attn=AttnConfig(rope_theta=10000.0, causal=False),
        layer_pattern=("attn",), moe_pattern=(False,),
        is_encoder=True, tie_embeddings=True, norm_kind="layernorm",
        act="gelu", dtype="float32")


@dataclasses.dataclass
class ExpertSpec:
    name: str
    cfg: ModelConfig
    train_mixture: dict            # domain -> weight used for training
    recency: float = 0.5           # 0 = ancient, 1 = brand new
    source: str = "in-repo"
    params: Optional[dict] = None  # filled after training
    n_params: int = 0

    def describe(self) -> str:
        """Model-card text used by the keyword-router baseline."""
        doms = sorted(self.train_mixture, key=self.train_mixture.get,
                      reverse=True)[:3]
        return (f"{self.name}: masked language model, {self.n_params} "
                f"parameters, specialized for {', '.join(doms)}.")


def _mix(*focus, w=0.8):
    """Mixture concentrated on focus domains, smoothed over all."""
    base = {d: (1.0 - w) / len(DOMAINS) for d in DOMAINS}
    for f in focus:
        base[f] += w / len(focus)
    return base


def paper_library_specs(vocab=512) -> list[ExpertSpec]:
    """11 experts mirroring the paper's library composition."""
    uniform = {d: 1.0 / len(DOMAINS) for d in DOMAINS}
    E = _enc
    return [
        # generalists at four sizes (bert-tiny .. roberta analogues)
        ExpertSpec("roberta-analog",    E("roberta-analog", 6, 256, 8, 1024, vocab), uniform, 0.8),
        ExpertSpec("bert-base-analog",  E("bert-base-analog", 4, 192, 6, 768, vocab), uniform, 0.5),
        ExpertSpec("bert-small-analog", E("bert-small-analog", 4, 128, 4, 512, vocab), uniform, 0.5),
        ExpertSpec("bert-tiny-analog",  E("bert-tiny-analog", 2, 64, 2, 256, vocab), uniform, 0.5),
        # specialists
        ExpertSpec("codebert-analog",   E("codebert-analog", 4, 160, 4, 640, vocab), _mix("github", "stackexchange"), 0.7),
        ExpertSpec("cppmodel-analog",   E("cppmodel-analog", 4, 160, 4, 640, vocab), _mix("github", "dm_math"), 0.6),
        ExpertSpec("patentbert-analog", E("patentbert-analog", 4, 160, 4, 640, vocab), _mix("uspto"), 0.4),
        ExpertSpec("clinbert-analog",   E("clinbert-analog", 4, 160, 4, 640, vocab), _mix("pubmed"), 0.4),
        ExpertSpec("lawbert-analog",    E("lawbert-analog", 4, 160, 4, 640, vocab), _mix("freelaw", "uspto"), 0.3),
        ExpertSpec("mathbert-analog",   E("mathbert-analog", 3, 128, 4, 512, vocab), _mix("dm_math"), 0.6),
        ExpertSpec("bookbert-analog",   E("bookbert-analog", 4, 160, 4, 640, vocab), _mix("books", "commoncrawl"), 0.5),
    ]


@dataclasses.dataclass
class ModelLibrary:
    experts: list[ExpertSpec]

    def __len__(self):
        return len(self.experts)

    def __getitem__(self, i) -> ExpertSpec:
        return self.experts[i]

    @property
    def names(self):
        return [e.name for e in self.experts]

    def sizes(self) -> np.ndarray:
        return np.array([e.n_params for e in self.experts], float)

    def recencies(self) -> np.ndarray:
        return np.array([e.recency for e in self.experts], float)

    def set_params(self, name: str, params, n_params: int):
        for e in self.experts:
            if e.name == name:
                e.params = params
                e.n_params = n_params
                return
        raise KeyError(name)
