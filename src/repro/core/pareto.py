"""Pareto-front exploration (paper Fig. 5).

Sweep the constraint weight lambda over [0, 2^4]; at each lambda route
every eval prompt, measure aggregate MLM accuracy and expected compute
(mean selected-model size).  The paper's headline: ~5% accuracy traded for
>50% compute.
"""

from __future__ import annotations

import numpy as np

from repro.core.library import ModelLibrary
from repro.core.objective import Constraint, routing_scores


def pareto_sweep(pred_losses: np.ndarray, qtable: dict,
                 library: ModelLibrary, constraint: Constraint,
                 lambdas=None) -> dict:
    """pred_losses: (N, n_models) router predictions (or the ground-truth
    Q-table for the oracle front).  Returns per-lambda metrics."""
    if lambdas is None:
        lambdas = np.concatenate([[0.0], np.logspace(-3, 4, 22, base=2.0)])
    sizes = library.sizes()
    acc_tab = qtable["acc"]
    N = pred_losses.shape[0]
    rows = []
    for lam in lambdas:
        scores = np.asarray(routing_scores(pred_losses, [constraint], [lam]))
        choice = scores.argmin(axis=1)
        acc = float(acc_tab[np.arange(N), choice].mean())
        mean_size = float(sizes[choice].mean())
        alloc = np.bincount(choice, minlength=len(library)) / N
        rows.append({"lam": float(lam), "accuracy": acc,
                     "mean_size": mean_size,
                     "size_frac": mean_size / sizes.max(),
                     "alloc": alloc.tolist()})
    return {"lambdas": [r["lam"] for r in rows], "rows": rows}
