"""Model-selection baselines for the Fig. 3a comparison.

The paper compares Tryage to Gorilla and GPT-3.5-Turbo — both select a
model from natural-language model cards, without learned loss prediction.
Offline we implement that class of baseline faithfully-in-kind:

  * ``keyword_router`` — the Gorilla analogue: scores each expert's
    model-card text against surface statistics of the prompt (which
    domain's private sub-vocabulary dominates), then picks the
    best-described match.  No learned loss prediction.
  * ``leaderboard_router`` — picks the single model with best mean
    benchmark accuracy (what an engineer does with a leaderboard).
  * ``random_router`` / ``largest_router`` — control floors.
"""

from __future__ import annotations

import numpy as np

from repro.core.library import ModelLibrary
from repro.data.corpus import DOMAINS, DomainCorpus


def oracle_choices(qtable: dict) -> np.ndarray:
    return qtable["loss"].argmin(axis=1)


def random_router(n_prompts: int, n_models: int, seed=0) -> np.ndarray:
    return np.random.default_rng(seed).integers(0, n_models, n_prompts)


def largest_router(library: ModelLibrary, n_prompts: int) -> np.ndarray:
    return np.full(n_prompts, int(library.sizes().argmax()))


def leaderboard_router(qtable_train: dict, n_prompts: int) -> np.ndarray:
    """Best-mean-accuracy model on held-out 'benchmark' data, applied
    uniformly (leaderboard-style selection)."""
    best = int(qtable_train["acc"].mean(axis=0).argmax())
    return np.full(n_prompts, best)


def keyword_router(tokens: np.ndarray, corpus: DomainCorpus,
                   library: ModelLibrary) -> np.ndarray:
    """Gorilla-class baseline: infer the dominant domain of each prompt
    from private-vocabulary hit counts, then pick the expert whose model
    card names that domain (ties -> larger model).  No learned Q."""
    V = corpus.vocab_size
    # map token -> domain by private vocab membership (-1 = shared)
    tok2dom = np.full(V, -1, np.int32)
    for di, d in enumerate(DOMAINS):
        tok2dom[corpus.private_vocab[d]] = di
    doms = tok2dom[tokens]                      # (N, S)
    counts = np.stack([(doms == di).sum(axis=1)
                       for di in range(len(DOMAINS))], axis=1)
    dom_choice = counts.argmax(axis=1)          # (N,)

    # expert affinity for each domain from its model card (train mixture
    # is what the card advertises)
    affinity = np.zeros((len(DOMAINS), len(library)))
    sizes = library.sizes()
    for mi, e in enumerate(library.experts):
        for di, d in enumerate(DOMAINS):
            affinity[di, mi] = e.train_mixture.get(d, 0.0)
    # tie-break toward larger models (Gorilla's observed bias)
    affinity += 1e-9 * (sizes / sizes.max())[None, :]
    return affinity.argmax(axis=1)[dom_choice]


def selection_accuracy(choices: np.ndarray, qtable: dict,
                       tol: float = 0.0) -> float:
    """Fraction of prompts routed to the argmin-loss model (Fig. 3a).

    ``tol`` > 0 counts near-optimal picks (loss within tol of the best) —
    mirrors the paper's lenient 'any evidence' scoring of GPT/Gorilla.
    """
    loss = qtable["loss"]
    best = loss.min(axis=1)
    picked = loss[np.arange(len(choices)), choices]
    return float(np.mean(picked <= best + tol))
