"""The single designated entry point for minting PRNG keys in library
code.

``jax.random.PRNGKey(0)`` literals scattered through ``src/`` make seed
provenance untraceable and silently correlate draws across unrelated
call sites — jaxlint's JXL002 flags them.  Library code mints its root
key here; callers that need independent streams split the result.
Tests, benchmarks and scripts are entry points and may still use
explicit literals.
"""

from __future__ import annotations

import jax


def seeded_key(seed: int = 0) -> jax.Array:
    """Root PRNG key for library-internal use (abstract init passes,
    deterministic default initialisation).  Split before consuming."""
    return jax.random.PRNGKey(seed)
