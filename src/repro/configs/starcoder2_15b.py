"""StarCoder2-15B [arXiv:2402.19173].

GQA kv=4, RoPE, native 4096-token sliding-window attention on every layer
(which is what qualifies it for the long_500k decode shape).
"""

from repro.models.common import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    attn=AttnConfig(rope_theta=100_000.0, qkv_bias=True,
                    sliding_window=4096, window_pattern="all_local"),
    layer_pattern=("attn",),
    moe_pattern=(False,),
    tie_embeddings=True,
    norm_kind="layernorm",
    act="gelu",
    source="arXiv:2402.19173",
)
