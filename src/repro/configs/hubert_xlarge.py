"""HuBERT-XLarge [arXiv:2106.07447].

Encoder-only (bidirectional, no decode shapes).  The mel/conv feature
extractor frontend is a stub: ``input_specs`` supplies precomputed frame
embeddings (B, T, d).  Targets are 504 k-means cluster ids (masked
prediction), so vocab=504 and the head is untied.
"""

from repro.models.common import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    attn=AttnConfig(rope_theta=0.0, causal=False),  # conv-pos stub, bidirectional
    layer_pattern=("attn",),
    moe_pattern=(False,),
    is_encoder=True,
    tie_embeddings=False,
    norm_kind="layernorm",
    act="gelu",
    embed_inputs=False,
    source="arXiv:2106.07447",
)
