"""Grok-1 314B MoE [hf:xai-org/grok-1]. 8 experts, top-2; GQA kv=8."""

from repro.models.common import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    attn=AttnConfig(rope_theta=10000.0, softcap=30.0),
    moe=MoEConfig(num_experts=8, top_k=2),
    layer_pattern=("attn",),
    moe_pattern=(True,),
    tie_embeddings=True,
    embed_scale=True,
    source="hf:xai-org/grok-1",
)
