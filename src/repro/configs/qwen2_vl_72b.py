"""Qwen2-VL-72B language backbone [arXiv:2409.12191].

VLM: the SigLIP-style ViT frontend + merger is a stub — ``input_specs``
supplies precomputed patch+text embeddings (B, S, d).  The backbone uses
M-RoPE (temporal/height/width sections) and QKV bias, per the paper.
"""

from repro.models.common import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    attn=AttnConfig(rope_theta=1_000_000.0, use_mrope=True,
                    mrope_sections=(16, 24, 24), qkv_bias=True),
    layer_pattern=("attn",),
    moe_pattern=(False,),
    tie_embeddings=False,
    source="arXiv:2409.12191",
)
