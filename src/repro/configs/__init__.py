"""Assigned-architecture configs + the paper's own Tryage library config.

Each module exposes ``CONFIG`` (exact assigned spec).  ``get_config(name)``
resolves by id; ``list_archs()`` enumerates the pool.
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "qwen2_vl_72b",
    "qwen15_05b",
    "jamba_v01_52b",
    "grok1_314b",
    "qwen2_moe_a27b",
    "hubert_xlarge",
    "tinyllama_11b",
    "starcoder2_15b",
    "xlstm_13b",
    "gemma3_4b",
]

_ALIASES = {
    "qwen2-vl-72b": "qwen2_vl_72b",
    "qwen1.5-0.5b": "qwen15_05b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "grok-1-314b": "grok1_314b",
    "qwen2-moe-a2.7b": "qwen2_moe_a27b",
    "hubert-xlarge": "hubert_xlarge",
    "tinyllama-1.1b": "tinyllama_11b",
    "starcoder2-15b": "starcoder2_15b",
    "xlstm-1.3b": "xlstm_13b",
    "gemma3-4b": "gemma3_4b",
}


def get_config(name: str):
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", ""))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def list_archs():
    return list(ARCH_IDS)
