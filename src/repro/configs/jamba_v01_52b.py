"""Jamba-v0.1 52B hybrid [arXiv:2403.19887].

Repeating 8-layer unit, attention:mamba = 1:7 (attention at in-unit index
4), MoE MLP every other layer (16 experts, top-2).
"""

from repro.models.common import AttnConfig, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    attn=AttnConfig(rope_theta=0.0),   # Jamba uses no positional encoding
    moe=MoEConfig(num_experts=16, top_k=2),
    ssm=SSMConfig(kind="mamba", d_state=16, d_conv=4, expand=2),
    layer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    moe_pattern=(False, True, False, True, False, True, False, True),
    tie_embeddings=False,
    source="arXiv:2403.19887",
)
