"""Qwen1.5-0.5B dense decoder [hf:Qwen/Qwen1.5-0.5B]. QKV bias; MHA (kv=16)."""

from repro.models.common import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    attn=AttnConfig(rope_theta=1_000_000.0, qkv_bias=True),
    layer_pattern=("attn",),
    moe_pattern=(False,),
    tie_embeddings=True,
    source="hf:Qwen/Qwen1.5-0.5B",
)
