"""Gemma-3-4B [hf:google/gemma-3-1b-pt family].

5:1 local(1024-window):global attention pattern, 128k context, head_dim
256, huge (262144) vocabulary, sqrt(d) embedding scaling.  34 layers = 5
full 6-layer units + 4 remainder (local) layers.
"""

from repro.models.common import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    d_ff=10240,
    vocab_size=262144,
    head_dim=256,
    attn=AttnConfig(rope_theta=1_000_000.0, sliding_window=1024,
                    window_pattern="gemma", global_every=6),
    layer_pattern=("attn",) * 6,
    moe_pattern=(False,) * 6,
    tie_embeddings=True,
    embed_scale=True,
    max_seq_len=131072,
    source="hf:google/gemma-3-1b-pt",
)
