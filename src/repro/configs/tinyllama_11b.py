"""TinyLlama-1.1B [arXiv:2401.02385]. Llama-2 architecture, GQA kv=4."""

from repro.models.common import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    attn=AttnConfig(rope_theta=10000.0),
    layer_pattern=("attn",),
    moe_pattern=(False,),
    tie_embeddings=False,
    source="arXiv:2401.02385",
)
