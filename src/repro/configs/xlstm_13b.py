"""xLSTM-1.3B [arXiv:2405.04517].

xLSTM[7:1]: repeating 8-layer unit of 7 mLSTM blocks + 1 sLSTM block.
d_ff=0 per the assignment: blocks carry their own internal up/down
projections (mLSTM pf=2) and there is no separate MLP.
"""

from repro.models.common import AttnConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    attn=AttnConfig(rope_theta=0.0),
    ssm=SSMConfig(kind="mlstm", num_heads=4, expand=2),
    layer_pattern=("mlstm", "mlstm", "mlstm", "mlstm",
                   "mlstm", "mlstm", "mlstm", "slstm"),
    moe_pattern=(False,) * 8,
    tie_embeddings=True,
    norm_kind="layernorm",
    source="arXiv:2405.04517",
)
