"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

60 routed experts (top-4, d_ff 1408 each) + 4 shared experts.
"""

from repro.models.common import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    attn=AttnConfig(rope_theta=1_000_000.0, qkv_bias=True),
    moe=MoEConfig(num_experts=60, top_k=4, num_shared_experts=4),
    layer_pattern=("attn",),
    moe_pattern=(True,),
    tie_embeddings=False,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
