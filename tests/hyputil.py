"""Optional-hypothesis shim for the property-based tests.

``hypothesis`` is a dev-only dependency: CI installs it, local
environments may not.  Importing ``given``/``settings``/``st`` from here
instead of from ``hypothesis`` lets a module keep its deterministic
invariant tests runnable everywhere while ONLY the property-based tests
skip when the library is absent — the old whole-module
``pytest.importorskip`` guard threw the deterministic tests away too.

When hypothesis is missing:
  - ``st.<anything>(...)`` returns an inert placeholder, so strategy
    expressions at decoration time still evaluate;
  - ``@given(...)`` replaces the test with a skip-marked stub (the test
    shows up as SKIPPED, not silently absent);
  - ``@settings(...)`` is the identity.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                                      # pragma: no cover
    HAS_HYPOTHESIS = False

    class _InertStrategies:
        """Evaluates any ``st.xxx(...)`` strategy expression to None."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _InertStrategies()

    def given(*_a, **_k):
        def deco(fn):
            # zero-arg stub: strategy args and pytest fixtures in the
            # wrapped signature must not be resolved for a skipped test
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass  # pragma: no cover

            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn


__all__ = ["HAS_HYPOTHESIS", "given", "settings", "st"]
