"""Fused-kernel serving path + shape-bucketed expert execution.

Deliberately hypothesis-free so these invariants run even when the
optional property-testing dep is absent (test_serving.py skips then).
"""

import jax
import numpy as np
import pytest

from repro.core.objective import recency_constraint, size_constraint
from repro.core.router import RouterConfig, init_router
from repro.data.batching import mlm_batch
from repro.serving import Request, TryageEngine, bucket_size


@pytest.fixture(scope="module")
def engines(tiny_library):
    """(reference, fused) engines over the same library/router weights."""
    lib = tiny_library
    rc = RouterConfig(n_models=3, vocab_size=64, num_layers=1, d_model=32,
                      num_heads=2, d_ff=64)
    rp, _ = init_router(jax.random.PRNGKey(9), rc)
    cons = [size_constraint(lib), recency_constraint(lib)]
    return (TryageEngine(lib, rp, rc, cons, max_batch=8, use_kernel=False),
            TryageEngine(lib, rp, rc, cons, max_batch=8, use_kernel=True))


def _requests(n, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(4, 64, size=(n, 32)).astype(np.int32)
    mb = mlm_batch(toks, rng, 0.2, 64)
    mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]
    return [Request(uid=i, tokens=mb["tokens"][i], targets=mb["targets"][i],
                    mask=mb["mask"][i], lambdas=mix[i % len(mix)])
            for i in range(n)]


def test_bucket_size():
    assert [bucket_size(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]


def test_route_batch_return_contract(engines):
    ref, fused = engines
    reqs = _requests(5, seed=0)
    for eng in (ref, fused):
        pred, choice = eng._route_batch(reqs)
        assert pred.shape == (5, 3) and pred.dtype == np.float32
        assert choice.shape == (5,)
        assert all(0 <= int(c) < 3 for c in choice)


def test_fused_matches_reference_choices(engines):
    """Mixed-flag workload with a ragged tail (21 % 8 != 0): the fused
    on-device decision must pick the same experts as the host path."""
    ref, fused = engines
    for r in _requests(21, seed=1):
        ref.submit(r)
    for r in _requests(21, seed=1):
        fused.submit(r)
    res_ref = sorted(ref.run(), key=lambda r: r.uid)
    res_fused = sorted(fused.run(), key=lambda r: r.uid)
    assert [r.expert for r in res_ref] == [r.expert for r in res_fused]
    for a, b in zip(res_ref, res_fused):
        np.testing.assert_allclose(a.pred_losses, b.pred_losses, atol=1e-5)


def test_loss_computed_when_targets_supplied(engines):
    _, fused = engines
    for r in _requests(9, seed=2):
        fused.submit(r)
    out = fused.run()
    assert len(out) == 9
    for r in out:
        assert r.loss is not None and np.isfinite(r.loss) and r.loss >= 0
        assert r.accuracy is not None and 0.0 <= r.accuracy <= 1.0


def test_loss_matches_direct_cross_entropy(engines):
    """Engine-reported loss == models.model.cross_entropy on the same
    request through the same expert."""
    import jax.numpy as jnp
    from repro.models.model import cross_entropy, forward
    _, fused = engines
    (req,) = _requests(1, seed=5)
    fused.submit(req)
    (res,) = fused.run()
    e = next(e for e in fused.library.experts if e.name == res.expert)
    logits, _, _ = forward(e.params, e.cfg, {"tokens": jnp.asarray(req.tokens[None])},
                           mode="train", remat=False)
    ce = cross_entropy(logits, jnp.asarray(req.targets[None]),
                       jnp.asarray(req.mask[None]))
    np.testing.assert_allclose(res.loss, float(ce), rtol=1e-5)


def test_loss_none_without_targets(engines):
    _, fused = engines
    fused.submit(Request(uid=0, tokens=np.ones(32, np.int32)))
    (r,) = fused.run()
    assert r.loss is None and r.accuracy is None


def test_bucket_stats_accounting(engines):
    _, fused = engines
    fused.stats.bucket_hits.clear()
    fused.stats.padded_rows = 0
    for r in _requests(11, seed=3):
        fused.submit(r)
    out = fused.run()
    assert len(out) == 11
    hits = fused.stats.bucket_hits
    assert hits, "bucketed execution must record launches"
    assert all(k & (k - 1) == 0 for k in hits)          # power-of-two shapes
    assert sum(k * v for k, v in hits.items()) == 11 + fused.stats.padded_rows


def test_buckets_disabled_runs_exact_shapes(engines):
    lib = engines[1].library
    rc = engines[1].rc
    eng = TryageEngine(lib, engines[1].router_params, rc,
                       engines[1].constraints, max_batch=8, use_kernel=True,
                       buckets=False)
    for r in _requests(5, seed=4):
        eng.submit(r)
    out = eng.run()
    assert len(out) == 5
    assert eng.stats.padded_rows == 0
