import jax
import jax.numpy as jnp

from repro.optim import (adamw_init, adamw_update, cosine_schedule,
                         exp_decay_schedule, warmup_cosine_schedule)


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array(2.0)}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(params, g, opt, lr=5e-2, weight_decay=0.0)
    assert float(loss(params)) < 1e-3


def test_adamw_grad_clip():
    params = {"w": jnp.array([1.0])}
    opt = adamw_init(params)
    g = {"w": jnp.array([1e9])}
    p2, _ = adamw_update(params, g, opt, lr=1e-2, grad_clip=1.0)
    assert abs(float(p2["w"][0] - params["w"][0])) < 0.1


def test_adamw_moments_f32_for_bf16_params():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    opt = adamw_init(params)
    assert opt.mu["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4,), jnp.bfloat16) * 0.1}
    p2, o2 = adamw_update(params, g, opt, lr=1e-2)
    assert p2["w"].dtype == jnp.bfloat16


def test_schedules():
    s = exp_decay_schedule(1.0, 0.9, 10)
    assert abs(float(s(10)) - 0.9) < 1e-6
    c = cosine_schedule(1.0, 100, min_frac=0.1)
    assert float(c(0)) == 1.0
    assert abs(float(c(100)) - 0.1) < 1e-6
    w = warmup_cosine_schedule(1.0, 10, 110)
    assert float(w(0)) == 0.0
    assert abs(float(w(10)) - 1.0) < 1e-6
