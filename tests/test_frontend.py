"""Serving front end: admission-queue shed policy, round-robin session
multiplexing, and the ample-capacity parity contract against plain
``engine.serve()``.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.objective import recency_constraint, size_constraint
from repro.core.router import RouterConfig, init_router
from repro.data.batching import mlm_batch
from repro.serving import (AdmissionQueue, Request, ServingFrontend,
                           Session, TryageEngine)

RC = RouterConfig(n_models=3, vocab_size=64, num_layers=1, d_model=32,
                  num_heads=2, d_ff=64)


class Clock:
    def __init__(self, t=1.0):
        self.t = t

    def __call__(self):
        return self.t


@pytest.fixture(scope="module")
def router_params():
    rp, _ = init_router(jax.random.PRNGKey(9), RC)
    return rp


def _requests(n, seed=0, priority=None):
    rng = np.random.default_rng(seed)
    toks = rng.integers(4, 64, size=(n, 32)).astype(np.int32)
    mb = mlm_batch(toks, rng, 0.2, 64)
    mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]
    return [Request(uid=i, tokens=mb["tokens"][i], targets=mb["targets"][i],
                    mask=mb["mask"][i], lambdas=mix[i % len(mix)],
                    priority=i % 3 if priority is None else priority)
            for i in range(n)]


def _engine(library, params, clock, **kw):
    cons = [size_constraint(library), recency_constraint(library)]
    kw.setdefault("max_batch", 32)
    return TryageEngine(library, params, RC, cons, now_fn=clock, **kw)


def _result_key(r):
    d = dataclasses.asdict(r)
    d["pred_losses"] = d["pred_losses"].tobytes()
    d["predictions"] = d["predictions"].tobytes()
    return d


def _stub(uid, priority):
    """Queue-level tests never touch the router, so token payloads can
    be empty."""
    z = np.zeros(0, np.int32)
    return Request(uid=uid, tokens=z, targets=z, mask=np.zeros(0, bool),
                   priority=priority)


# ----------------------------------------------------- admission queue


def test_queue_admits_fifo_under_capacity():
    q = AdmissionQueue(4)
    for i in range(4):
        assert q.offer(_stub(i, priority=i)) is None
    assert len(q) == 4 and q.peak == 4
    assert [q.pop().uid for _ in range(4)] == [0, 1, 2, 3]
    assert q.pop() is None


def test_queue_sheds_incoming_on_tie():
    """At capacity with equal priorities, the newest request loses —
    queued work is never displaced by an equal."""
    q = AdmissionQueue(2)
    q.offer(_stub(0, 1))
    q.offer(_stub(1, 1))
    shed = q.offer(_stub(2, 1))
    assert shed is not None and shed.uid == 2
    assert [q.pop().uid, q.pop().uid] == [0, 1]


def test_queue_sheds_lower_priority_incoming():
    q = AdmissionQueue(2)
    q.offer(_stub(0, 5))
    q.offer(_stub(1, 5))
    shed = q.offer(_stub(2, 1))
    assert shed.uid == 2


def test_queue_evicts_oldest_lowest_priority_for_higher():
    """A higher-priority arrival displaces the oldest queued request at
    the minimum priority; FIFO order among survivors is preserved."""
    q = AdmissionQueue(3)
    q.offer(_stub(0, 1))
    q.offer(_stub(1, 0))
    q.offer(_stub(2, 0))          # two at the minimum: uid 1 is oldest
    shed = q.offer(_stub(3, 2))
    assert shed.uid == 1
    assert [q.pop().uid for _ in range(3)] == [0, 2, 3]


def test_queue_peak_tracks_high_water_mark():
    q = AdmissionQueue(8)
    for i in range(5):
        q.offer(_stub(i, 0))
    for _ in range(5):
        q.pop()
    q.offer(_stub(9, 0))
    assert q.peak == 5 and len(q) == 1


def test_queue_capacity_validation():
    with pytest.raises(AssertionError):
        AdmissionQueue(0)


# ------------------------------------------------------- multiplexing


def test_frontend_round_robin_interleaves(tiny_library, router_params):
    """One item per live session per sweep: session order in the
    admitted stream interleaves rather than draining one session
    first."""
    clock = Clock()
    eng = _engine(tiny_library, router_params, clock)
    reqs = _requests(6, priority=0)
    sess = [Session("a", reqs[0:3]), Session("b", reqs[3:6])]
    fe = ServingFrontend(eng, sess, capacity=16)
    admitted = [r.uid for r in fe._multiplex() if r is not None]
    assert admitted == [0, 3, 1, 4, 2, 5]
    assert eng.stats.admitted == 6 and eng.stats.sessions == 2


def test_frontend_skips_idle_ticks_and_yields_none(tiny_library,
                                                   router_params):
    """``None`` items in a session are idle ticks: not admitted, but a
    sweep with nothing due still yields ``None`` so deadline flushes can
    fire."""
    clock = Clock()
    eng = _engine(tiny_library, router_params, clock)
    reqs = _requests(2, priority=0)
    sess = [Session("a", [None, reqs[0], None, None, reqs[1]])]
    out = list(ServingFrontend(eng, sess, capacity=4)._multiplex())
    uids = [r.uid for r in out if r is not None]
    assert uids == [0, 1]
    assert out.count(None) == 3       # the sweeps where nothing was due


def test_frontend_stamps_arrival_time(tiny_library, router_params):
    clock = Clock(t=7.5)
    eng = _engine(tiny_library, router_params, clock)
    req = _requests(1, priority=0)[0]
    assert req.arrival is None
    fe = ServingFrontend(eng, [Session("a", [req])], capacity=4)
    out = [r for r in fe._multiplex() if r is not None]
    assert out[0].arrival == 7.5


def test_frontend_sheds_and_accounts(tiny_library, router_params):
    """Capacity 1 with a 4-deep burst in one sweep: the three
    lowest-priority requests shed, counted by priority, and never reach
    the engine."""
    clock = Clock()
    eng = _engine(tiny_library, router_params, clock)
    reqs = [_stub(0, 0), _stub(1, 2), _stub(2, 1), _stub(3, 0)]
    # all four arrive before the first pop: one session each
    sess = [Session(f"s{i}", [r]) for i, r in enumerate(reqs)]
    fe = ServingFrontend(eng, sess, capacity=1)
    admitted = [r.uid for r in fe._multiplex() if r is not None]
    assert admitted == [1]            # only the priority-2 request
    assert eng.stats.shed == 3
    assert eng.stats.admitted == 1
    assert dict(eng.stats.shed_by_priority) == {0: 2, 1: 1}
    assert sorted(fe.shed_uids) == [0, 2, 3]


# ------------------------------------------------------------- parity


def test_frontend_ample_capacity_matches_plain_serve(tiny_library,
                                                     router_params):
    """With capacity well above the burst size, the frontend is a pure
    reordering-free relay: identical Results and identical engine stats
    (modulo the frontend's own counters) vs plain ``engine.serve()``
    over the same requests in the same order."""
    outs, summaries = [], []
    for use_frontend in (False, True):
        clock = Clock()
        eng = _engine(tiny_library, router_params, clock, lane_target=8,
                      max_wait_s=1e9)
        reqs = _requests(48, seed=3)
        if use_frontend:
            fe = ServingFrontend(eng, [Session("all", reqs)], capacity=256)
            out = list(fe.serve())
            assert fe.shed_uids == []
        else:
            out = list(eng.serve(iter(reqs)))
        outs.append(sorted(out, key=lambda r: r.uid))
        s = eng.stats.summary()
        summaries.append(s)
    for a, b in zip(*outs):
        assert _result_key(a) == _result_key(b)
    sf, sp = summaries[1], summaries[0]
    assert sf["frontend"]["shed"] == 0
    for key in sp:
        if key == "frontend":
            continue
        assert sf[key] == sp[key]
