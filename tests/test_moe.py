import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig, MoEConfig
from repro.models.moe import apply_moe, init_moe


def _cfg(E=4, k=2, shared=0, cap=2.0):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=32, num_heads=2,
        num_kv_heads=2, d_ff=64, vocab_size=64,
        moe=MoEConfig(num_experts=E, top_k=k, num_shared_experts=shared,
                      capacity_factor=cap),
        moe_pattern=(True,), dtype="float32")


def test_moe_shapes_and_finite(key):
    cfg = _cfg()
    p, _ = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 8, 32))
    y, aux = apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all() and jnp.isfinite(aux)
    assert float(aux) > 0.0


def test_moe_matches_dense_computation_topk_equals_E(key):
    """With top_k == E and ample capacity, MoE == weighted sum of all
    experts; verify against an explicit dense loop."""
    cfg = _cfg(E=3, k=3, cap=8.0)
    p, _ = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 6, 32))
    y, _ = apply_moe(p, x, cfg)

    xt = x.reshape(-1, 32)
    logits = xt @ p["router"]
    w = jax.nn.softmax(logits, -1)
    dense = jnp.zeros_like(xt)
    for e in range(3):
        h = jax.nn.silu(xt @ p["wi"][e]) * (xt @ p["wg"][e])
        dense += w[:, e:e + 1] * (h @ p["wo"][e])
    np.testing.assert_allclose(np.asarray(y.reshape(-1, 32)),
                               np.asarray(dense), atol=2e-4)


def test_moe_capacity_drops_tokens(key):
    """With capacity factor near zero most tokens are dropped -> output
    (routed part) is near zero."""
    cfg = _cfg(E=2, k=1, cap=0.01)
    p, _ = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (4, 16, 32))
    y, _ = apply_moe(p, x, cfg)
    # capacity = max(k, ...) = 1 slot per expert -> at most 2 tokens routed
    nonzero_rows = (jnp.abs(y.reshape(-1, 32)).max(-1) > 1e-6).sum()
    assert int(nonzero_rows) <= 2


def test_moe_shared_experts_always_on(key):
    cfg = _cfg(E=2, k=1, shared=2, cap=0.01)
    p, _ = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 8, 32))
    y, _ = apply_moe(p, x, cfg)
    # even dropped tokens get the shared-expert contribution
    nonzero_rows = (jnp.abs(y.reshape(-1, 32)).max(-1) > 1e-6).sum()
    assert int(nonzero_rows) == 16


def test_moe_aux_loss_uniform_router_is_one(key):
    """Switch aux loss == 1.0 for a perfectly uniform router."""
    cfg = _cfg(E=4, k=1, cap=8.0)
    p, _ = init_moe(key, cfg, jnp.float32)
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])  # uniform probs
    x = jax.random.normal(key, (8, 32, 32))
    _, aux = apply_moe(p, x, cfg)
    # me = 1/E; ce depends on top-1 tie-break but sums to 1
    assert 0.9 < float(aux) < 1.6


def test_moe_grad_flows(key):
    cfg = _cfg()
    p, _ = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 8, 32))

    def loss(pp):
        y, aux = apply_moe(pp, x, cfg)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    gnorm = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
