import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def test_roundtrip(tmp_path, key):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                   "c": (jnp.zeros((2,)), jnp.array(3))},
    }
    path = str(tmp_path / "ckpt")
    save_pytree(path, tree)
    back = load_pytree(path)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # bf16 dtype preserved through npz (as uint16 view? must match)
    assert back["nested"]["b"].dtype == np.asarray(tree["nested"]["b"]).dtype


def test_manager_best_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for step, metric in [(1, 0.5), (2, 0.3), (3, 0.4), (4, 0.35)]:
        mgr.save(step, {"w": jnp.array(float(step))}, metric=metric)
    best = mgr.load_best()
    assert float(best["w"]) == 2.0  # step 2 had lowest metric
    # only last two step checkpoints retained
    files = {f for f in os.listdir(tmp_path) if f.startswith("step_")}
    assert len(files) == 4  # 2 steps x (npz + json)
    assert mgr.load_step(4) is not None
