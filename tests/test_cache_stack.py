"""Oracle-tested correctness harness for the tiered decision cache.

Three oracles pin the stack to reference behaviour:

* a flat dict (never evicts, never approximates) — under arbitrary
  get/put/evict/version-bump op streams the stack must agree with it on
  every verdict it returns (a T2-backed stack additionally never
  *forgets*, because T1 evictions fall back to the persistent tier);
* the PR-3 list-based LRU model — with T2/T3 disabled the stack IS the
  plain ``DecisionCache``, eviction order included;
* a brute-force NumPy distance scan — ``ExactNNIndex`` must return the
  exact nearest neighbour (it is an exact index with IVF pruning, not
  an approximate one), and the semantic tier must never serve a verdict
  scored by superseded router parameters (``VersionedParams.swap``
  forces revalidation).

Engine-level feature-off parity (the ``--cache-tiers exact``
acceptance gate) closes the file: a T1-only stack must be bit-for-bit
the plain cache on the 256-request mixed-flag workload, cascade and
adaptation traffic included.
"""

import dataclasses
import logging

import jax
import numpy as np
import pytest

from hyputil import given, settings, st
from repro.core.router import RouterConfig, VersionedParams, init_router
from repro.data.batching import mlm_batch
from repro.serving import (DecisionCache, DecisionCacheStack, ExactNNIndex,
                           MemoryKVStore, Request, SemanticCache,
                           TryageEngine, calibrate_eps)
from repro.serving import cache as cache_mod
from repro.serving.cache import decode_verdict, encode_key, encode_verdict

RC = RouterConfig(n_models=3, vocab_size=64, num_layers=1, d_model=32,
                  num_heads=2, d_ff=64)


def _key(k, version=0, lam=(), min_conf=0.0):
    lambdas = {"size": lam[0]} if lam else {}
    return DecisionCache.key(np.array([k], np.int32), lambdas,
                             ["size"], min_conf, version)


# ------------------------------------------------ stack vs flat-dict oracle


_stack_ops = st.lists(
    st.one_of(
        st.tuples(st.just("put"), st.integers(0, 5)),
        st.tuples(st.just("get"), st.integers(0, 5)),
        st.just("bump"),
    ),
    min_size=1, max_size=80)


@given(ops=_stack_ops, capacity=st.integers(1, 4), with_kv=st.booleans())
@settings(max_examples=60, deadline=None)
def test_stack_matches_flat_dict_oracle(ops, capacity, with_kv):
    """Under arbitrary get/put/version-bump streams (with T1 evictions
    forced by a tiny capacity) every verdict the stack returns matches a
    flat never-evicting dict oracle — i.e. the stack can forget (without
    T2) but can never answer *wrong*.  With T2 it must not forget
    either: the persistent tier backstops every T1 eviction."""
    stack = DecisionCacheStack(capacity,
                               kv=MemoryKVStore() if with_kv else None)
    oracle = {}
    version = 0
    for i, op in enumerate(ops):
        if op == "bump":
            version += 1
            stack.clear()                 # what the engine does on swap
            assert stack.stale_versions(version) == set()
            continue
        name, k = op
        key = _key(k, version)
        if name == "put":
            stack.put(key, np.full(3, i, np.float32), i % 3,
                      depth=i % 2, confidence=0.5)
            oracle[key] = i
        else:
            entry, tier = stack.lookup(key)
            if entry is not None:
                # never a wrong verdict, from any tier
                assert key in oracle, (i, tier)
                want = oracle[key]
                assert entry[1] == want % 3 and entry[0][0] == want
                assert tier in ("t1", "t2")
            elif with_kv:
                # never a forgotten verdict either, with T2 on
                assert key not in oracle
        assert len(stack) <= capacity


# ---------------------------------------------- T1 LRU parity (T2/T3 off)


class _LRUOracle:
    """The PR-3 list-based LRU reference: MRU at the end."""

    def __init__(self, capacity):
        self.capacity = capacity
        self.items = []

    def get(self, key):
        for i, (k, v) in enumerate(self.items):
            if k == key:
                self.items.append(self.items.pop(i))
                return v
        return None

    def put(self, key, value):
        self.items = [(k, v) for k, v in self.items if k != key]
        self.items.append((key, value))
        while len(self.items) > self.capacity:
            self.items.pop(0)


@given(ops=st.lists(st.tuples(st.sampled_from(["get", "put"]),
                              st.integers(0, 5)),
                    min_size=1, max_size=60),
       capacity=st.integers(1, 4))
@settings(max_examples=60, deadline=None)
def test_t1_only_stack_matches_lru_oracle(ops, capacity):
    """With T2/T3 disabled the stack's hit/miss/eviction behaviour is
    the plain LRU — same model test the plain cache passes in
    tests/test_scheduler.py."""
    stack = DecisionCacheStack(capacity)
    oracle = _LRUOracle(capacity)
    for i, (op, k) in enumerate(ops):
        key = _key(k)
        if op == "get":
            hit, tier = stack.lookup(key)
            expect = oracle.get(key)
            if expect is None:
                assert hit is None and tier == ""
            else:
                assert hit is not None and hit[1] == expect % 3
                assert tier == "t1"
        else:
            stack.put(key, np.zeros(3, np.float32), i % 3)
            oracle.put(key, i)
        assert len(stack) == len(oracle.items) <= capacity
    for k, v in oracle.items:             # same survivors, same recency
        hit = stack.get(k)
        assert hit is not None and hit[1] == v % 3


# --------------------------------------------------- T2 codec round-trip


@given(version=st.integers(0, 2**40), min_conf=st.sampled_from([0.0, 0.9]),
       lam=st.lists(st.floats(0, 16, allow_nan=False), max_size=3),
       toks=st.lists(st.integers(0, 63), min_size=1, max_size=8))
@settings(max_examples=60, deadline=None)
def test_key_codec_is_injective_on_distinct_keys(version, min_conf, lam,
                                                 toks):
    """encode_key is a pure function of the key tuple, and distinct
    tuples get distinct bytes (spot-checked on systematic neighbours)."""
    arr = np.array(toks, np.int32)
    key = (arr.tobytes(), arr.dtype.str, arr.shape, tuple(lam),
           float(min_conf), int(version))
    enc = encode_key(key)
    assert enc == encode_key(key)
    neighbours = [
        (arr.tobytes(), arr.dtype.str, arr.shape, tuple(lam),
         float(min_conf), version + 1),
        (arr.tobytes(), arr.dtype.str, arr.shape, tuple(lam) + (1.0,),
         float(min_conf), version),
        ((arr + 1).astype(np.int32).tobytes(), arr.dtype.str, arr.shape,
         tuple(lam), float(min_conf), version),
    ]
    for other in neighbours:
        assert encode_key(other) != enc


def test_verdict_codec_round_trip():
    pred = np.array([0.5, 1.25, -3.0], np.float32)
    out = decode_verdict(encode_verdict(pred, 2, 1, 0.75))
    np.testing.assert_array_equal(out[0], pred)
    assert out[1:] == (2, 1, 0.75)
    assert not out[0].flags.writeable


# ------------------------------------------- T3: exact-NN vs brute force


_nn_ops = st.lists(
    st.one_of(
        st.tuples(st.just("add"),
                  st.lists(st.integers(-5, 5), min_size=3, max_size=3)),
        st.tuples(st.just("discard"), st.integers(0, 30)),
        st.tuples(st.just("query"),
                  st.lists(st.integers(-5, 5), min_size=3, max_size=3)),
    ),
    min_size=1, max_size=60)


@given(ops=_nn_ops)
@settings(max_examples=80, deadline=None)
def test_nn_index_matches_brute_force_scan(ops):
    """ExactNNIndex.query == NumPy brute-force argmin over the live set,
    across arbitrary add/discard interleavings (rebuilds forced by a
    tiny min_build so IVF pruning is actually exercised)."""
    index = ExactNNIndex(3, min_build=4)
    live = {}                             # id -> vector mirror
    ids = []
    for op, val in ops:
        if op == "add":
            v = np.array(val, np.float32)
            idx = index.add(v)
            assert idx not in live        # stable ids: never two live users
            live[idx] = v
            ids.append(idx)
        elif op == "discard":
            if ids:
                idx = ids[val % len(ids)]
                index.discard(idx)
                live.pop(idx, None)
        else:
            q = np.array(val, np.float32)
            got = index.query(q)
            if not live:
                assert got is None
                continue
            d2 = {i: float(((v - q) ** 2).sum()) for i, v in live.items()}
            best = min(d2.values())
            assert got is not None
            gid, gd2 = got
            assert gd2 == pytest.approx(best)
            assert d2[gid] == pytest.approx(best)   # any tie is legal
        assert len(index) == len(live)


# ----------------------------- T3: swap forces revalidation (no escapes)


@given(n=st.integers(1, 12), seed=st.integers(0, 99),
       bumps=st.integers(1, 3))
@settings(max_examples=40, deadline=None)
def test_no_pre_swap_verdict_escapes_semantic_tier(n, seed, bumps):
    """Every verdict cached before ``VersionedParams.swap`` must be
    rejected (status "stale", then tombstoned) at the new version — for
    any query point, including the exact stored embeddings."""
    rng = np.random.default_rng(seed)
    vp = VersionedParams({"w": 0})
    sem = SemanticCache(eps=100.0)        # generous bound: distance
    stack = DecisionCacheStack(4, semantic=sem)    # never saves a stale hit
    embs = rng.normal(size=(n, 8)).astype(np.float32)
    keys = [_key(i, vp.version) for i in range(n)]
    for i in range(n):
        stack.put(keys[i], np.zeros(3, np.float32), i % 3, emb=embs[i])
    # sanity: everything hits at the live version
    for i in range(n):
        entry, status = stack.lookup_semantic(embs[i], keys[i], vp.version)
        assert status == "hit" and entry[1] == i % 3
    for _ in range(bumps):
        vp = vp.swap({"w": vp.version + 1})
    assert stack.stale_versions(vp.version) == {0}
    for i in range(n):
        probe_key = _key(i, vp.version)
        entry, status = stack.lookup_semantic(embs[i], probe_key,
                                              vp.version)
        assert entry is None and status in ("stale", "miss")
    # every reject tombstoned its entry: the tier is now empty and clean
    assert len(sem) == 0
    assert sem.stale_versions(vp.version) == set()
    # T1 still holds the version-0 keys (the engine clears them on swap)
    # but they are unreachable: probes at the live version key-miss them
    stack.clear()
    assert stack.stale_versions(vp.version) == set()


def test_semantic_context_is_exact_not_approximate():
    """Same embedding under a different lambda vector or threshold is a
    different context: T3 never crosses the knobs that change the right
    verdict."""
    sem = SemanticCache(eps=10.0)
    emb = np.ones(4, np.float32)
    k_a = _key(0, 0, lam=(1.0,))
    sem.put(emb, (k_a[3], k_a[4]), 0, np.zeros(3), 1)
    for other in (_key(0, 0, lam=(2.0,)), _key(0, 0, lam=(1.0,),
                                               min_conf=0.9)):
        entry, status = sem.get(emb, (other[3], other[4]), 0)
        assert entry is None and status == "miss"
    entry, status = sem.get(emb + 0.1, (k_a[3], k_a[4]), 0)
    assert status == "hit" and entry[1] == 1


def test_calibrate_eps_margin_of_closest_disagreeing_pair():
    emb = np.array([[0.0, 0.0], [3.0, 4.0], [0.0, 1.0]])
    verdicts = np.array([0, 1, 0])
    # closest disagreeing pair is (index 1, index 2): distance sqrt(18)
    assert calibrate_eps(emb, verdicts, margin=0.5) == \
        pytest.approx(0.5 * np.sqrt(18))
    assert calibrate_eps(emb, np.zeros(3)) == np.inf


# ------------------------------------------- dropped-lambda observability


def test_unknown_lambda_flag_warns_once_and_counts(caplog):
    cache_mod._warned_lambda_names.clear()
    toks = np.arange(4, dtype=np.int32)
    drops = []
    with caplog.at_level(logging.WARNING, logger="repro.serving.cache"):
        k1 = DecisionCache.key(toks, {"sise": 1.0}, ["size"],
                               unknown_sink=drops.extend)
        k2 = DecisionCache.key(toks, {"sise": 2.0}, ["size"],
                               unknown_sink=drops.extend)
        k3 = DecisionCache.key(toks, {"size": 2.0}, ["size"],
                               unknown_sink=drops.extend)
    # every drop is counted, but the warning fires once per name
    assert drops == ["sise", "sise"]
    warned = [r for r in caplog.records if "sise" in r.getMessage()]
    assert len(warned) == 1
    # the dropped flag cannot affect the key (that is the bug: two
    # different misspelled weights collide) — hence it must be observable
    assert k1 == k2 and k1 != k3


def test_engine_counts_dropped_lambda(tiny_library):
    cache_mod._warned_lambda_names.clear()
    rp, _ = init_router(jax.random.PRNGKey(9), RC)
    from repro.core.objective import recency_constraint, size_constraint
    eng = TryageEngine(tiny_library, rp, RC,
                       [size_constraint(tiny_library),
                        recency_constraint(tiny_library)], max_batch=8)
    reqs = _requests(4, seed=5)
    reqs[0].lambdas = {"sise": 1.0}
    reqs[1].lambdas = {"syze": 2.0, "size": 1.0}
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert eng.stats.cache_key_dropped_lambda == 2
    assert eng.stats.summary()["cache"]["dropped_lambda"] == 2


# ------------------------------------------------ engine-level contracts


def _requests(n, seed=0, min_confidence=0.0, n_unique=None):
    n_unique = n if n_unique is None else n_unique
    rng = np.random.default_rng(seed)
    toks = rng.integers(4, 64, size=(n_unique, 32)).astype(np.int32)
    mb = mlm_batch(toks, rng, 0.2, 64)
    mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]
    return [Request(uid=i, tokens=mb["tokens"][i % n_unique],
                    targets=mb["targets"][i % n_unique],
                    mask=mb["mask"][i % n_unique],
                    lambdas=mix[i % len(mix)],
                    min_confidence=min_confidence)
            for i in range(n)]


class _Clock:
    def __init__(self, t=1.0):
        self.t = t

    def __call__(self):
        return self.t


def _engine(library, params, **kw):
    from repro.core.objective import recency_constraint, size_constraint
    cons = [size_constraint(library), recency_constraint(library)]
    kw.setdefault("max_batch", 32)
    kw.setdefault("now_fn", _Clock())
    return TryageEngine(library, params, RC, cons, **kw)


def _result_key(r):
    d = dataclasses.asdict(r)
    d["pred_losses"] = d["pred_losses"].tobytes()
    d["predictions"] = d["predictions"].tobytes()
    return d


@pytest.mark.parametrize("min_conf,adapt", [(0.99, 0), (0.0, 8)])
def test_t1_only_stack_is_bit_for_bit_the_plain_cache(tiny_library,
                                                      min_conf, adapt):
    """Feature-off parity (the ``--cache-tiers exact`` gate): an engine
    whose cache is a T1-only ``DecisionCacheStack`` reproduces the plain
    ``DecisionCache`` engine exactly — identical Results and EngineStats
    on the 256-request mixed-flag workload, cascade (min_conf=0.99) and
    adaptation (adapt_every=8) traffic included."""
    rp, _ = init_router(jax.random.PRNGKey(9), RC)
    outs, stats = [], []
    for flavour in ("plain", "stack"):
        eng = _engine(tiny_library, rp, adapt_every=adapt,
                      replay_cap=256 if adapt else 0)
        assert type(eng.cache) is DecisionCache
        if flavour == "stack":
            eng.cache = DecisionCacheStack(eng.cache.capacity)
        for r in _requests(256, seed=7, min_confidence=min_conf,
                           n_unique=192):
            eng.submit(r)
        out = eng.run()
        assert len(out) == 256
        outs.append(sorted(out, key=lambda r: r.uid))
        stats.append(eng.stats.summary())
    for a, b in zip(*outs):
        assert _result_key(a) == _result_key(b)
    assert stats[0] == stats[1]
    hits = stats[0]["cache"]["hits"]
    if adapt == 0:
        assert hits == 64                 # 64/256 repeats, no version bumps
    assert stats[0]["cache"]["tiers"] == ({"t1": hits} if hits else {})


def test_replicas_share_verdicts_through_t2(tiny_library):
    """Two engine replicas over one KV store: the second replica serves
    the first's traffic entirely from T2, with identical verdicts —
    the restart/multi-process story, hermetically."""
    rp, _ = init_router(jax.random.PRNGKey(9), RC)
    kv = MemoryKVStore()
    reqs = lambda: _requests(48, seed=11, n_unique=48)  # noqa: E731
    a = _engine(tiny_library, rp, cache_kv=kv)
    for r in reqs():
        a.submit(r)
    first = {r.uid: r for r in a.run()}
    assert a.stats.cache_hits == 0
    b = _engine(tiny_library, rp, cache_kv=kv)
    for r in reqs():
        b.submit(r)
    second = {r.uid: r for r in b.run()}
    assert b.stats.cache_hits == 48
    assert dict(b.stats.cache_tier_hits) == {"t2": 48}
    for uid, r in second.items():
        assert r.cached and r.expert == first[uid].expert
        np.testing.assert_array_equal(r.pred_losses,
                                      first[uid].pred_losses)


def test_semantic_tier_serves_paraphrases_with_oracle_verdicts(
        tiny_library):
    """End-to-end T3: paraphrased repeats (a few flipped tokens) hit the
    semantic tier, and every served verdict equals what a fresh score
    would have produced (zero wrong routings — the bench_cache gate, in
    miniature)."""
    rp, _ = init_router(jax.random.PRNGKey(9), RC)
    base = _requests(24, seed=13, n_unique=24)
    rng = np.random.default_rng(5)
    para = _requests(24, seed=13, n_unique=24)
    for i, r in enumerate(para):
        t = r.tokens.copy()
        t[rng.integers(0, t.shape[0])] = rng.integers(4, 64)
        r.tokens, r.uid = t, 1000 + i

    eng = _engine(tiny_library, rp, cache_semantic_eps=1.0)
    for r in base:
        eng.submit(r)
    eng.run()
    for r in para:
        eng.submit(r)
    served = {r.uid: r for r in eng.run()}
    t3 = eng.stats.cache_tier_hits["t3"]
    assert t3 > 0
    assert eng.stats.cache_revalidations >= t3

    # oracle: fresh engine scores the same paraphrases from scratch
    oracle = _engine(tiny_library, rp)
    for r in _requests(24, seed=13, n_unique=24):
        pass                              # rebuild para deterministically
    fresh = _requests(24, seed=13, n_unique=24)
    rng = np.random.default_rng(5)
    for i, r in enumerate(fresh):
        t = r.tokens.copy()
        t[rng.integers(0, t.shape[0])] = rng.integers(4, 64)
        r.tokens, r.uid = t, 1000 + i
        oracle.submit(r)
    for uid, r in {r.uid: r for r in oracle.run()}.items():
        assert served[uid].expert == r.expert   # zero wrong routings


def test_engine_invariant_holds_across_tiers_after_swap(tiny_library):
    """Adaptation with every tier live: post-swap, no served verdict was
    scored by superseded parameters (`_assert_cache_version` runs inside
    the engine on every swap; here we double-check the telemetry)."""
    rp, _ = init_router(jax.random.PRNGKey(9), RC)
    eng = _engine(tiny_library, rp, cache_kv=MemoryKVStore(),
                  cache_semantic_eps=1.0, adapt_every=8, replay_cap=256)
    for r in _requests(96, seed=17, n_unique=48):
        eng.submit(r)
    eng.run()
    assert eng.stats.router_version > 0   # at least one swap happened
    assert eng.cache.stale_versions(eng.router_version) == set()
