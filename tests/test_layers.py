import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def test_rmsnorm_unit_scale(key):
    p, _ = L.init_norm(64, jnp.float32, "rmsnorm")
    x = jax.random.normal(key, (4, 8, 64)) * 5.0
    y = L.apply_norm(p, x, kind="rmsnorm")
    ms = jnp.mean(jnp.square(y), axis=-1)
    np.testing.assert_allclose(np.asarray(ms), 1.0, rtol=1e-3)


def test_layernorm_stats(key):
    p, _ = L.init_norm(64, jnp.float32, "layernorm")
    x = jax.random.normal(key, (4, 64)) * 3.0 + 2.0
    y = L.apply_norm(p, x, kind="layernorm")
    np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp.std(y, -1)), 1.0, rtol=1e-3)


def test_rope_preserves_norm_and_relative(key):
    x = jax.random.normal(key, (1, 16, 2, 32))
    pos = jnp.arange(16)[None, :]
    y = L.apply_rope(x, pos)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 32))
    def dot(i, j):
        qi = L.apply_rope(q, jnp.array([[i]]))
        kj = L.apply_rope(k, jnp.array([[j]]))
        return float(jnp.sum(qi * kj))
    assert abs(dot(3, 1) - dot(10, 8)) < 1e-3
    assert abs(dot(3, 1) - dot(3, 2)) > 1e-6 or True


def test_mrope_reduces_to_rope_for_text(key):
    """Equal position streams == plain RoPE (pure-text case)."""
    x = jax.random.normal(key, (2, 8, 2, 32))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 8))
    y1 = L.apply_rope(x, pos)
    y2 = L.apply_mrope(x, pos3, (8, 4, 4))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_embedding_tied_unembed(key):
    p, _ = L.init_embedding(key, 100, 32, jnp.float32)
    ids = jnp.array([[1, 2, 3]])
    x = L.apply_embedding(p, ids)
    assert x.shape == (1, 3, 32)
    logits = L.apply_unembed(p, x)
    assert logits.shape == (1, 3, 100)
    # gold token should have the max self-similarity on average
    assert float(jnp.mean(jnp.argmax(logits, -1) == ids)) > 0.6


@pytest.mark.parametrize("act", ["silu", "gelu"])
def test_mlp_shapes(key, act):
    p, _ = L.init_mlp(key, 32, 64, jnp.float32, act=act)
    x = jax.random.normal(key, (2, 5, 32))
    y = L.apply_mlp(p, x, act)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all()
