"""Serving-engine invariants (incl. hypothesis property tests).

Deterministic tests run everywhere; only the property-based tests skip
when hypothesis is absent (see ``hyputil``)."""

import jax
import numpy as np
import pytest

from hyputil import given, settings, st

from repro.core.objective import recency_constraint, size_constraint
from repro.core.router import RouterConfig, init_router
from repro.data.batching import mlm_batch
from repro.serving import Request, TryageEngine, parse_flags


@pytest.fixture(scope="module")
def tiny_engine(tiny_library):
    """Engine over the shared 3-expert tiny library (conftest.py)."""
    rc = RouterConfig(n_models=3, vocab_size=64, num_layers=1, d_model=32,
                      num_heads=2, d_ff=64)
    rp, _ = init_router(jax.random.PRNGKey(9), rc)
    return TryageEngine(tiny_library, rp, rc,
                        [size_constraint(tiny_library),
                         recency_constraint(tiny_library)],
                        max_batch=8)


def _requests(n, seed=0, lam=None):
    rng = np.random.default_rng(seed)
    toks = rng.integers(4, 64, size=(n, 32)).astype(np.int32)
    mb = mlm_batch(toks, rng, 0.2, 64)
    return [Request(uid=i, tokens=mb["tokens"][i], targets=mb["targets"][i],
                    mask=mb["mask"][i], lambdas=lam or {})
            for i in range(n)]


def test_every_request_served_exactly_once(tiny_engine):
    reqs = _requests(21, seed=1)
    for r in reqs:
        tiny_engine.submit(r)
    results = tiny_engine.run()
    assert sorted(r.uid for r in results) == list(range(21))
    assert not tiny_engine.queue


def test_size_flag_shrinks_selected_models(tiny_engine):
    sizes = {e.name: e.n_params for e in tiny_engine.library.experts}
    for r in _requests(16, seed=2):
        tiny_engine.submit(r)
    plain = tiny_engine.run()
    for r in _requests(16, seed=2, lam={"size": 50.0}):
        tiny_engine.submit(r)
    constrained = tiny_engine.run()
    mean_plain = np.mean([sizes[r.expert] for r in plain])
    mean_constr = np.mean([sizes[r.expert] for r in constrained])
    assert mean_constr <= mean_plain
    assert all(r.expert == "small" for r in constrained)


def test_results_carry_predictions_and_flops(tiny_engine):
    for r in _requests(5, seed=3):
        tiny_engine.submit(r)
    for res in tiny_engine.run():
        assert res.pred_losses.shape == (3,)
        assert res.predictions.shape == (32,)
        assert res.flops_proxy > 0
        assert res.accuracy is None or 0.0 <= res.accuracy <= 1.0


def test_stats_accounting(tiny_engine):
    tiny_engine.stats.served = 0
    tiny_engine.stats.per_expert.clear()
    for r in _requests(12, seed=4):
        tiny_engine.submit(r)
    tiny_engine.run()
    assert tiny_engine.stats.served == 12
    assert sum(tiny_engine.stats.per_expert.values()) == 12


@given(st.lists(st.sampled_from(
    ["", "[Flag: Smallest model]", "[Flag: small model]",
     "[Flag: Newest model]", "x [flag: smallest model] y"]),
    min_size=1, max_size=5))
@settings(max_examples=25, deadline=None)
def test_parse_flags_properties(texts):
    lam = parse_flags(" ".join(texts))
    assert all(v >= 0 for v in lam.values())
    assert set(lam) <= {"size", "recency"}
    if any("mallest" in t for t in texts):
        assert lam.get("size", 0) >= 8.0
