"""Loop-aware HLO accounting: scanned and unrolled forms of the same
computation must report identical dot FLOPs (the roofline's key input)."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_loops import loop_aware_totals


@pytest.fixture(scope="module")
def wx():
    W = jax.random.normal(jax.random.PRNGKey(0), (8, 64, 64))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    return W, x


def test_scan_equals_unroll_flops(wx):
    W, x = wx

    def scanned(x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, x, W)[0].sum()

    def unrolled(x):
        h = x
        for i in range(8):
            h = jnp.tanh(h @ W[i])
        return h.sum()

    f_scan = loop_aware_totals(
        jax.jit(scanned).lower(x).compile().as_text())["dot_flops"]
    f_unroll = loop_aware_totals(
        jax.jit(unrolled).lower(x).compile().as_text())["dot_flops"]
    expected = 8 * 2 * 4 * 64 * 64
    assert f_scan == expected
    assert f_unroll == expected


def test_nested_scan_multiplies(wx):
    W, x = wx

    def nested(x):
        def outer(h, _):
            def inner(h2, w):
                return jnp.tanh(h2 @ w), None
            h, _ = jax.lax.scan(inner, h, W)
            return h, None
        return jax.lax.scan(outer, x, None, length=3)[0].sum()

    f = loop_aware_totals(
        jax.jit(nested).lower(x).compile().as_text())["dot_flops"]
    assert f == 3 * 8 * 2 * 4 * 64 * 64


def test_traffic_and_collectives_nonnegative(wx):
    W, x = wx
    tot = loop_aware_totals(
        jax.jit(lambda x: (x @ W[0]).sum()).lower(x).compile().as_text())
    assert tot["traffic_bytes"] > 0
    assert tot["collective_bytes"] == 0
