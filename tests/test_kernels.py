"""Per-kernel correctness: sweep shapes/dtypes, assert_allclose vs ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mlstm_scan.ops import mlstm_chunkwise
from repro.kernels.mlstm_scan.ref import mlstm_ref
from repro.kernels.router_score.kernel import router_score_fused
from repro.kernels.router_score.ref import router_score_ref


# ------------------------------------------------------- flash attention

FLASH_CASES = [
    # B, S, H, KV, hd, causal, window, softcap, dtype
    (2, 128, 4, 4, 64, True, 0, 0.0, jnp.float32),
    (1, 256, 4, 2, 64, True, 0, 0.0, jnp.float32),
    (2, 128, 2, 1, 128, True, 32, 0.0, jnp.float32),
    (1, 128, 2, 2, 64, False, 0, 0.0, jnp.float32),
    (1, 128, 2, 2, 64, True, 0, 30.0, jnp.float32),
    (1, 128, 4, 2, 64, True, 0, 0.0, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,H,KV,hd,causal,window,cap,dtype", FLASH_CASES)
def test_flash_attention_vs_ref(B, S, H, KV, hd, causal, window, cap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          block_q=64, block_k=64)
    kr, vr = jnp.repeat(k, H // KV, 2), jnp.repeat(v, H // KV, 2)
    tb = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    ref = attention_ref(tb(q), tb(kr), tb(vr), causal=causal, window=window,
                        softcap=cap)
    ref = ref.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_attention_block_size_invariance():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    o1 = flash_attention(q, k, v, block_q=32, block_k=64)
    o2 = flash_attention(q, k, v, block_q=128, block_k=128)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


# ------------------------------------------------------- router score

@pytest.mark.parametrize("B,d,hid,M,nc,block_b", [
    (16, 64, 32, 11, 2, 16),
    (37, 128, 64, 11, 2, 16),   # non-divisible batch -> padding path
    (64, 128, 128, 5, 1, 64),
    (8, 32, 16, 3, 3, 8),
])
def test_router_score_vs_ref(B, d, hid, M, nc, block_b):
    ks = jax.random.split(jax.random.PRNGKey(2), 7)
    emb = jax.random.normal(ks[0], (B, d))
    w1 = jax.random.normal(ks[1], (d, hid)) * 0.1
    b1 = jax.random.normal(ks[2], (hid,)) * 0.1
    w2 = jax.random.normal(ks[3], (hid, M)) * 0.1
    b2 = jax.random.normal(ks[4], (M,)) * 0.1
    cv = jax.random.uniform(ks[5], (nc, M))
    lam = jax.random.uniform(ks[6], (B, nc)) * 2
    p1, c1 = router_score_fused(emb, w1, b1, w2, b2, cv, lam,
                                block_b=block_b)
    p2, c2 = router_score_ref(emb, w1, b1, w2, b2, cv, lam)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=1e-5)
    assert bool((c1 == c2).all())


def test_router_route_matches_objective_route():
    """Parity: the fused decision (interpret mode) must match
    ``objective.routing_scores`` + ``route`` in f32 for random per-request
    lambdas, including the padded tail (B % block_b != 0).  Scores agree to
    1 ulp (batch tiling changes XLA CPU vectorization, so strict bitwise
    equality over different tile shapes is not attainable); the selected
    expert must agree exactly on every request."""
    from repro.core.objective import Constraint, route, routing_scores
    from repro.kernels.router_score.ops import router_route

    B, d, hid, M, nc, block_b = 37, 64, 32, 7, 2, 16   # 37 % 16 != 0
    ks = jax.random.split(jax.random.PRNGKey(7), 7)
    emb = jax.random.normal(ks[0], (B, d))
    head = {"w1": jax.random.normal(ks[1], (d, hid)) * 0.1,
            "b1": jax.random.normal(ks[2], (hid,)) * 0.1,
            "w2": jax.random.normal(ks[3], (hid, M)) * 0.1,
            "b2": jax.random.normal(ks[4], (M,)) * 0.1}
    cv = np.asarray(jax.random.uniform(ks[5], (nc, M)), np.float32)
    lam = np.asarray(jax.random.uniform(ks[6], (B, nc)) * 2, np.float32)

    pred, choice = router_route(emb, head, cv, lam, block_b=block_b,
                                interpret=True)
    pred, choice = np.asarray(pred), np.asarray(choice)
    assert pred.dtype == np.float32 and pred.shape == (B, M)

    # same head math in f32, to within a single ulp
    pred_ref, choice_ref = router_score_ref(
        emb, head["w1"], head["b1"], head["w2"], head["b2"],
        jnp.asarray(cv), jnp.asarray(lam))
    np.testing.assert_allclose(pred, np.asarray(pred_ref), rtol=2.4e-7,
                               atol=1.2e-7)
    np.testing.assert_array_equal(choice, np.asarray(choice_ref))

    # decision parity against the reference objective, request by request
    cons = [Constraint(f"c{j}", cv[j]) for j in range(nc)]
    for i in range(B):
        s = np.asarray(routing_scores(pred[i], cons, [float(v) for v in lam[i]]))
        assert s.dtype == np.float32
        assert int(choice[i]) == int(route(pred[i], cons,
                                           [float(v) for v in lam[i]]))


def test_router_route_no_constraints_is_pure_argmin():
    """n_c=0 surface: a zero constraint row + zero lambda column leaves the
    decision at argmin of the predicted losses."""
    from repro.core.objective import constraint_matrix
    from repro.kernels.router_score.ops import router_route

    B, d, hid, M = 5, 16, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    emb = jax.random.normal(ks[0], (B, d))
    head = {"w1": jax.random.normal(ks[1], (d, hid)) * 0.1,
            "b1": jax.random.normal(ks[2], (hid,)) * 0.1,
            "w2": jax.random.normal(ks[3], (hid, M)) * 0.1,
            "b2": jax.random.normal(ks[4], (M,)) * 0.1}
    cv = constraint_matrix([], M)
    lam = np.zeros((B, 1), np.float32)
    pred, choice = router_route(emb, head, cv, lam, interpret=True)
    np.testing.assert_array_equal(np.asarray(choice),
                                  np.asarray(pred).argmin(axis=1))


@pytest.mark.parametrize("B", [1, 3, 127, 1000])
def test_router_score_padded_tail_sweep(B):
    """Every tail shape the launch plan produces — a single fully-padded
    tile (B=1, 3), a ragged multi-tile tail (127 % 32 != 0) and a
    serving-scale batch (1000 % 128 != 0) — must match the oracle."""
    d, hid, M, nc = 32, 16, 5, 2
    block_b = 128 if B >= 128 else 32
    ks = jax.random.split(jax.random.PRNGKey(B), 7)
    emb = jax.random.normal(ks[0], (B, d))
    w1 = jax.random.normal(ks[1], (d, hid)) * 0.1
    b1 = jax.random.normal(ks[2], (hid,)) * 0.1
    w2 = jax.random.normal(ks[3], (hid, M)) * 0.1
    b2 = jax.random.normal(ks[4], (M,)) * 0.1
    cv = jax.random.uniform(ks[5], (nc, M))
    lam = jax.random.uniform(ks[6], (B, nc)) * 2
    p1, c1 = router_score_fused(emb, w1, b1, w2, b2, cv, lam,
                                block_b=block_b)
    p2, c2 = router_score_ref(emb, w1, b1, w2, b2, cv, lam)
    assert p1.shape == (B, M) and c1.shape == (B,)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


# ------------------------------------------------------- mlstm chunkwise

@pytest.mark.parametrize("B,S,H,dh,chunk", [
    (1, 64, 1, 16, 16),
    (2, 128, 2, 32, 32),
    (1, 128, 2, 64, 64),
    (2, 96, 1, 32, 32),  # 3 chunks
])
def test_mlstm_chunkwise_vs_ref(B, S, H, dh, chunk):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 3.0
    st = {"C": jnp.zeros((B, H, dh, dh)), "n": jnp.zeros((B, H, dh)),
          "m": jnp.zeros((B, H))}
    h, st1 = mlstm_chunkwise(q, k, v, ig, fg, st, chunk=chunk)
    tb = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    tb2 = lambda a: a.transpose(0, 2, 1).reshape(B * H, S)
    hr, Cr, nr, mr = mlstm_ref(
        tb(q), tb(k), tb(v), tb2(ig), tb2(fg),
        st["C"].reshape(B * H, dh, dh), st["n"].reshape(B * H, dh),
        st["m"].reshape(B * H))
    hr = hr.reshape(B, H, S, dh).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st1["C"].reshape(B * H, dh, dh)),
                               np.asarray(Cr), atol=5e-4, rtol=1e-3)


def test_mlstm_chunkwise_carries_state():
    """Running two halves with carried state == one full run."""
    B, S, H, dh = 1, 64, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 3.0
    z = {"C": jnp.zeros((B, H, dh, dh)), "n": jnp.zeros((B, H, dh)),
         "m": jnp.zeros((B, H))}
    h_full, _ = mlstm_chunkwise(q, k, v, ig, fg, z, chunk=16)
    h1, st = mlstm_chunkwise(q[:, :32], k[:, :32], v[:, :32],
                             ig[:, :32], fg[:, :32], z, chunk=16)
    h2, _ = mlstm_chunkwise(q[:, 32:], k[:, 32:], v[:, 32:],
                            ig[:, 32:], fg[:, 32:], st, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(h_full), atol=5e-4, rtol=1e-3)


def test_mlstm_kernel_is_model_impl():
    """The pallas path of mlstm_full matches the xla path."""
    from repro.models import ssm
    from repro.models.common import ModelConfig, SSMConfig
    cfg = ModelConfig(name="t", family="ssm", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=64,
                      ssm=SSMConfig(kind="mlstm", num_heads=2, expand=2),
                      layer_pattern=("mlstm",), moe_pattern=(False,),
                      dtype="float32")
    p, _ = ssm.init_mlstm(jax.random.PRNGKey(5), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 64, 32)) * 0.5
    y_xla, _ = ssm.mlstm_full(p, x, cfg, impl="xla")
    y_pl, _ = ssm.mlstm_full(p, x, cfg, impl="pallas")
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pl),
                               atol=5e-4, rtol=1e-3)
