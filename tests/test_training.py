"""Training-loop behaviour: expert MLM training learns, router regression
fits the Q-table, early stopping triggers."""

import jax
import numpy as np

from repro.core.library import ExpertSpec, _enc, _mix
from repro.core.router import RouterConfig, init_router, predict_losses
from repro.core.training import train_expert, train_router
from repro.data.batching import BatchIterator
from repro.data.corpus import DOMAINS


def test_expert_training_reduces_loss(corpus):
    spec = ExpertSpec("t", _enc("t", 2, 64, 2, 128, 512),
                      _mix("github", w=0.8))
    import jax.numpy as jnp
    from repro.models.model import init_model, lm_loss
    it = BatchIterator(corpus, spec.train_mixture, 16, 64, seed=5)
    b0 = next(it)
    jb0 = {k: jnp.asarray(v) for k, v in b0.items() if k != "domain"}
    params0, _ = init_model(jax.random.PRNGKey(0), spec.cfg)
    l_before = float(lm_loss(params0, spec.cfg, jb0, remat=False)[0])
    train_expert(spec, corpus, steps=60, batch=16, seq=64, seed=0)
    l_after = float(lm_loss(spec.params, spec.cfg, jb0, remat=False)[0])
    assert l_after < l_before - 0.3
    assert spec.n_params > 0


def test_router_fits_synthetic_qtable(corpus):
    """Router must regress losses that depend on domain identity."""
    rng = np.random.default_rng(0)
    N, S, M = 256, 64, 3
    toks, labels = corpus.sample_mixture(
        {"github": 0.5, "uspto": 0.5}, N, S, rng)
    # synthetic targets: model 1 good on github, model 2 good on uspto
    gh = (labels == DOMAINS.index("github")).astype(np.float32)
    loss = np.stack([np.full(N, 2.0),
                     2.0 - gh,           # 1.0 on github, 2.0 on uspto
                     1.0 + gh], axis=1)  # 2.0 on github, 1.0 on uspto
    rc = RouterConfig(n_models=M, vocab_size=512, num_layers=2, d_model=64,
                      num_heads=2, d_ff=128)
    rp, _ = init_router(jax.random.PRNGKey(1), rc)
    rp, log = train_router(
        rp, rc, {"tokens": toks[:192], "loss": loss[:192]},
        {"tokens": toks[192:], "loss": loss[192:]},
        epochs=8, batch=32, lr=3e-4, verbose=False)
    pred = np.asarray(predict_losses(rp, rc, {"tokens": toks[192:]}))
    choice = pred.argmin(1)
    true_choice = loss[192:].argmin(1)
    assert (choice == true_choice).mean() > 0.8
    assert log.best_val < log.val_loss[0]


def test_early_stopping_on_flat_val(corpus):
    rng = np.random.default_rng(2)
    toks, _ = corpus.sample_mixture({"books": 1.0}, 64, 32, rng)
    loss = np.ones((64, 2), np.float32)  # constant target: converges fast
    rc = RouterConfig(n_models=2, vocab_size=512, num_layers=1, d_model=32,
                      num_heads=2, d_ff=64)
    rp, _ = init_router(jax.random.PRNGKey(2), rc)
    rp, log = train_router(
        rp, rc, {"tokens": toks[:48], "loss": loss[:48]},
        {"tokens": toks[48:], "loss": loss[48:]},
        epochs=50, batch=8, lr=1e-3, patience=4, verbose=False)
    assert log.stopped_early
