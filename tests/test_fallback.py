"""Health-driven fallback: the chain-walk selection rule and the
engine-level behaviour contracts.

Three layers:

* ``fallback_choice`` property (hypothesis): whenever the chain walk
  terminates at an available expert, its pick is *bit-for-bit* the
  lexicographic argmin of the same scores over the available experts —
  i.e. fallback is exactly "re-score with the unavailable experts
  masked out", never a different objective.
* Parity: an engine with a health tracker attached but every expert
  healthy produces identical Results and EngineStats to the
  health-unaware engine (``health=None``) — the PR-4 pipeline — under
  both disciplines; all traffic carries ``fallback_depth=0``.
* Failure paths: route-time fallback around a forced-down expert
  matches a host re-score reference (cache hits included), failed
  flushes re-route stranded entries with monotone ``fallback_depth``,
  and the no-fallback baseline fails them terminally.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.objective import (fallback_choice, recency_constraint,
                                  size_constraint)
from repro.core.router import RouterConfig, init_router
from repro.data.batching import mlm_batch
from repro.serving import ExpertHealth, Request, TryageEngine
from repro.serving.requests import lambda_matrix

from hyputil import given, settings, st

RC = RouterConfig(n_models=3, vocab_size=64, num_layers=1, d_model=32,
                  num_heads=2, d_ff=64)


class Clock:
    def __init__(self, t=1.0):
        self.t = t

    def __call__(self):
        return self.t


@pytest.fixture(scope="module")
def router_params():
    rp, _ = init_router(jax.random.PRNGKey(9), RC)
    return rp


def _requests(n, seed=0, n_unique=None):
    n_unique = n if n_unique is None else n_unique
    rng = np.random.default_rng(seed)
    toks = rng.integers(4, 64, size=(n_unique, 32)).astype(np.int32)
    mb = mlm_batch(toks, rng, 0.2, 64)
    mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]
    return [Request(uid=i, tokens=mb["tokens"][i % n_unique],
                    targets=mb["targets"][i % n_unique],
                    mask=mb["mask"][i % n_unique],
                    lambdas=mix[i % len(mix)])
            for i in range(n)]


def _engine(library, params, clock, **kw):
    cons = [size_constraint(library), recency_constraint(library)]
    kw.setdefault("max_batch", 32)
    return TryageEngine(library, params, RC, cons, now_fn=clock, **kw)


def _result_key(r):
    d = dataclasses.asdict(r)
    d["pred_losses"] = d["pred_losses"].tobytes()
    d["predictions"] = d["predictions"].tobytes()
    return d


def _lex_argmin(scores, mask):
    """The reference selection: argmin over masked-in experts with the
    same (score, index) tie-break fallback_choice uses."""
    cand = [i for i in range(len(scores)) if mask[i]]
    return min(cand, key=lambda i: (scores[i], i))


# ------------------------------------------------- fallback_choice rule


def _check_masked_rescore(seed):
    """Non-degraded fallback == lexicographic argmin over available
    experts of the *same* scores, bit-for-bit; degraded mode == first
    healthy expert in the escalation order."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(2, 7))
    scores = rng.normal(size=m)
    if rng.random() < 0.3:                # exercise exact-tie paths
        scores = np.round(scores)
    healthy = rng.random(m) < 0.7
    overloaded = rng.random(m) < 0.3
    available = healthy & ~overloaded
    choice = int(rng.integers(m))
    order = np.argsort(rng.permutation(m), kind="stable")
    max_depth = int(rng.integers(0, m + 2))

    final, depth, degraded = fallback_choice(
        scores, healthy, available, choice, order, max_depth)

    assert 0 <= final < m and depth >= 0
    if max_depth <= 0 or available[choice]:
        assert (final, depth, degraded) == (choice, 0, False)
    elif not degraded:
        assert available[final]
        assert 1 <= depth <= max_depth
        assert final == _lex_argmin(scores, available)
    else:
        expected = next((int(i) for i in order if healthy[i]),
                        int(order[0]))
        assert final == expected


@settings(max_examples=200, deadline=None)
@given(st.integers(0, 10 ** 9))
def test_fallback_choice_is_masked_rescore(seed):
    _check_masked_rescore(seed)


def test_fallback_choice_masked_rescore_sweep():
    """Deterministic stand-in for the hypothesis property when
    hypothesis is unavailable: the same check over a fixed seed grid."""
    for seed in range(300):
        _check_masked_rescore(seed)


def test_fallback_choice_depth_counts_walk():
    scores = np.array([0.0, 1.0, 2.0, 3.0])
    order = np.arange(4)
    ok = np.array([True] * 4)
    # choice unavailable, cheapest alternative available: one step
    avail = np.array([False, True, True, True])
    assert fallback_choice(scores, ok, avail, 0, order, 3) == (1, 1, False)
    # two cheapest unavailable: two steps to reach index 2
    avail = np.array([False, False, True, True])
    assert fallback_choice(scores, ok, avail, 0, order, 3) == (2, 2, False)
    # nothing available: degraded to the smallest healthy expert
    avail = np.zeros(4, bool)
    final, depth, degraded = fallback_choice(scores, ok, avail, 0, order, 3)
    assert degraded and final == 0


# --------------------------------------------------- all-healthy parity


@pytest.mark.parametrize("discipline", ["run", "serve"])
def test_all_healthy_engine_matches_health_unaware(tiny_library,
                                                   router_params,
                                                   discipline):
    """Health tracker attached + every expert healthy == health=None
    engine, bit-for-bit: identical Results (fallback_depth=0 throughout)
    and identical EngineStats."""
    outs, stats = [], []
    for health in (None, ExpertHealth(3, now_fn=Clock())):
        clock = Clock()
        eng = _engine(tiny_library, router_params, clock, lane_target=8,
                      max_wait_s=1e9, health=health)
        reqs = _requests(96, seed=3, n_unique=64)
        if discipline == "run":
            for r in reqs:
                eng.submit(r)
            out = eng.run()
        else:
            out = list(eng.serve(iter(reqs)))
        outs.append(sorted(out, key=lambda r: r.uid))
        stats.append(eng.stats.summary())
    for a, b in zip(*outs):
        assert _result_key(a) == _result_key(b)
        assert a.fallback_depth == 0 and not a.failed
    assert stats[0] == stats[1]
    assert stats[0]["fallback"]["fallbacks"] == 0


# ------------------------------------------------ route-time fallback


def test_route_time_fallback_matches_host_rescore(tiny_library,
                                                  router_params):
    """With one expert forced down, every admitted request's choice is
    bit-for-bit the masked re-score argmin under its own lambdas, and
    the Results carry the fallback depth."""
    clock = Clock()
    health = ExpertHealth(3, now_fn=clock)
    eng = _engine(tiny_library, router_params, clock, health=health,
                  fallback_max_depth=2)
    reqs = _requests(64, seed=5)

    # reference picks before any health signal
    pred, choice0 = eng._score_batch(reqs)
    scores = pred + lambda_matrix(reqs, eng._cnames) @ eng._cmat
    down = int(np.bincount(np.asarray(choice0), minlength=3).argmax())
    health.force_down(down)

    mask = np.ones(3, bool)
    mask[down] = False
    for r in reqs:
        eng.submit(r)
    results = sorted(eng.run(), key=lambda r: r.uid)
    assert len(results) == 64
    names = [e.name for e in tiny_library.experts]
    moved = 0
    for i, res in enumerate(results):
        expected = (_lex_argmin(scores[i], mask)
                    if int(choice0[i]) == down else int(choice0[i]))
        assert res.expert == names[expected]
        if int(choice0[i]) == down:
            moved += 1
            assert res.fallback_depth >= 1
        else:
            assert res.fallback_depth == 0
    assert moved > 0
    assert eng.stats.fallbacks == moved
    assert eng.stats.degraded == 0


def test_fallback_applies_to_cache_hits(tiny_library, router_params):
    """Health is time-varying and must never be memoised: a cached
    verdict whose expert has since gone down is re-routed at admission,
    still counting as a cache hit."""
    clock = Clock()
    health = ExpertHealth(3, now_fn=clock)
    eng = _engine(tiny_library, router_params, clock, health=health)
    req = _requests(1, seed=11)[0]
    eng.submit(req)
    first = eng.run()[0]
    assert not first.cached
    names = [e.name for e in tiny_library.experts]
    health.force_down(names.index(first.expert))
    eng.submit(_requests(1, seed=11)[0])
    second = eng.run()[0]
    assert second.cached                      # the verdict was memoised
    assert second.expert != first.expert      # ...but health re-applied
    assert second.fallback_depth >= 1


# ------------------------------------------------- failed-flush paths


def test_failed_flush_reroutes_with_fallback(tiny_library, router_params):
    """A persistent failure injection on the hot expert: every request
    still gets served (re-routed, monotone fallback_depth), the health
    tracker records the failures, and nothing fails terminally."""
    clock = Clock()
    health = ExpertHealth(3, now_fn=clock)
    eng = _engine(tiny_library, router_params, clock, lane_target=8,
                  max_wait_s=1e9, health=health, fallback_max_depth=2)
    reqs = _requests(64, seed=5)
    _, choice0 = eng._score_batch(reqs)
    hot = int(np.bincount(np.asarray(choice0), minlength=3).argmax())
    hot_name = tiny_library.experts[hot].name

    def stream():
        for i, r in enumerate(reqs):
            if i == 0:
                eng.scheduler.inject_failures(hot)   # every flush fails
            yield r

    results = sorted(eng.serve(stream()), key=lambda r: r.uid)
    assert len(results) == 64
    assert all(not r.failed for r in results)
    assert all(r.expert != hot_name for r in results)
    rerouted = [r for r in results if r.fallback_depth > 0]
    assert rerouted
    assert eng.stats.reroutes > 0
    assert eng.stats.failed == 0
    assert eng.stats.expert_failures[hot_name] >= 1
    assert not health.healthy(hot)
    assert eng.stats.served == 64


def test_failed_flush_without_fallback_fails_terminally(tiny_library,
                                                        router_params):
    """The health-unaware baseline: the same injection turns the hot
    expert's requests into terminal failed Results."""
    clock = Clock()
    eng = _engine(tiny_library, router_params, clock, lane_target=8,
                  max_wait_s=1e9)
    reqs = _requests(64, seed=5)
    _, choice0 = eng._score_batch(reqs)
    hot = int(np.bincount(np.asarray(choice0), minlength=3).argmax())
    n_hot = int((np.asarray(choice0) == hot).sum())
    hot_name = tiny_library.experts[hot].name

    def stream():
        for i, r in enumerate(reqs):
            if i == 0:
                eng.scheduler.inject_failures(hot)
            yield r

    results = sorted(eng.serve(stream()), key=lambda r: r.uid)
    assert len(results) == 64
    failed = [r for r in results if r.failed]
    assert len(failed) == n_hot > 0
    for r in failed:
        assert r.expert == hot_name
        assert r.flush_reason == "failed"
        assert r.predictions.size == 0 and r.loss is None
    assert eng.stats.failed == n_hot
    assert eng.stats.served == 64 - n_hot


def test_bounded_injection_recovers(tiny_library, router_params):
    """count=1 arms exactly one failure: the first flush of the lane
    fails, later flushes succeed."""
    clock = Clock()
    health = ExpertHealth(3, cooldown_s=0.0, failure_alpha=0.4,
                          now_fn=clock)
    eng = _engine(tiny_library, router_params, clock, lane_target=4,
                  max_wait_s=1e9, health=health, fallback_max_depth=2)
    reqs = _requests(64, seed=5)

    def stream():
        for i, r in enumerate(reqs):
            if i == 0:
                eng.scheduler.inject_failures(0, count=1)
            yield r

    results = list(eng.serve(stream()))
    assert len(results) == 64
    assert all(not r.failed for r in results)
    assert eng.stats.expert_failures[tiny_library.experts[0].name] <= 1
