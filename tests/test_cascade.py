"""Confidence-aware cascade routing: escalation, calibration, telemetry,
and the single-shot parity guarantee.

The parity tests are the contract the cascade subsystem was built under:
with ``min_confidence=0`` (the default) the engine must reproduce the
pre-cascade (PR 2) behaviour bit-for-bit — same expert choices, same
Result fields, same EngineStats — whether or not the router checkpoint
carries an uncertainty head.  Deliberately hypothesis-free so the whole
module runs without the optional property-testing dep.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.objective import (cascade_choice, confidence_scores,
                                  escalation_order, recency_constraint,
                                  route, size_constraint)
from repro.core.router import (RouterConfig, add_uncertainty_head,
                               init_router, predict_losses,
                               predict_uncertainty)
from repro.core.training import calibrate_uncertainty
from repro.data.batching import mlm_batch
from repro.serving import DecisionCache, Request, TryageEngine


RC = RouterConfig(n_models=3, vocab_size=64, num_layers=1, d_model=32,
                  num_heads=2, d_ff=64)


class Clock:
    def __init__(self, t=1.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture(scope="module")
def router_params():
    """(pre-cascade params, same params + retrofitted unc head)."""
    rp, _ = init_router(jax.random.PRNGKey(9), RC)
    return rp, add_uncertainty_head(jax.random.PRNGKey(3), rp, RC)


def _requests(n, seed=0, min_confidence=0.0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(4, 64, size=(n, 32)).astype(np.int32)
    mb = mlm_batch(toks, rng, 0.2, 64)
    mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]
    return [Request(uid=i, tokens=mb["tokens"][i], targets=mb["targets"][i],
                    mask=mb["mask"][i], lambdas=mix[i % len(mix)],
                    min_confidence=min_confidence)
            for i in range(n)]


def _engine(library, params, clock, **kw):
    cons = [size_constraint(library), recency_constraint(library)]
    kw.setdefault("max_batch", 8)
    return TryageEngine(library, params, RC, cons, now_fn=clock, **kw)


# ----------------------------------------------------- objective layer


def test_confidence_scores_monotone_and_bounded():
    sigma = np.array([[0.0, 0.5, 1.0, 4.0]])
    conf = confidence_scores(sigma)
    assert conf.shape == sigma.shape
    assert (np.diff(conf[0]) < 0).all()          # larger sigma, less trust
    assert (conf > 0).all() and (conf <= 1.0).all()


def test_escalation_order_is_ascending_sizes(tiny_library):
    order = escalation_order(tiny_library)
    sizes = tiny_library.sizes()
    assert sorted(order) == list(range(len(tiny_library)))
    assert (np.diff(sizes[order]) >= 0).all()


def test_cascade_choice_disabled_and_bounds():
    conf = np.array([0.1, 0.2, 0.3])
    order = [0, 1, 2]
    # disabled: threshold 0 or depth 0 pass the choice through
    assert cascade_choice(1, conf, 0.0, order, 4) == (1, 0)
    assert cascade_choice(1, conf, 0.9, order, 0) == (1, 0)
    # bounded depth: one step at a time, never past the ladder top
    assert cascade_choice(0, conf, 0.9, order, 1) == (1, 1)
    assert cascade_choice(0, conf, 0.9, order, 8) == (2, 2)
    assert cascade_choice(2, conf, 0.9, order, 8) == (2, 0)


def test_cascade_choice_stops_at_first_confident_expert():
    conf = np.array([0.1, 0.8, 0.3])
    assert cascade_choice(0, conf, 0.5, [0, 1, 2], 8) == (1, 1)


def test_cascade_choice_router_preferred_jump():
    """With constrained scores supplied, an escalation step jumps to the
    best-scoring expert among the strictly-larger ones."""
    conf = np.array([0.1, 0.1, 0.9, 0.9])
    scores = np.array([0.1, 0.5, 0.4, 0.2])
    order = [0, 1, 2, 3]
    # from 0, larger experts are {1,2,3}; best score among them is 3
    assert cascade_choice(0, conf, 0.5, order, 8, scores) == (3, 1)
    # depth bound still applies before the jump resolves confidence
    conf2 = np.array([0.1, 0.1, 0.1, 0.1])
    final, depth = cascade_choice(0, conf2, 0.5, order, 1, scores)
    assert (final, depth) == (3, 1)


def test_routing_scores_uncertainty_term_shifts_choice():
    pred = np.array([[0.30, 0.31, 0.32]])        # near-tie, 0 wins raw
    sigma = np.array([[5.0, 0.1, 0.2]])          # ... but 0 is untrusted
    assert int(route(pred)[0]) == 0
    assert int(route(pred, uncertainty=sigma, risk_weight=0.1)[0]) == 1


# --------------------------------------------------------- router layer


def test_predict_uncertainty_constant_prior_without_head(router_params):
    rp, _ = router_params
    toks = np.arange(1, 33, dtype=np.int32)[None].repeat(3, axis=0)
    sigma = np.asarray(predict_uncertainty(rp, RC, {"tokens": toks}))
    np.testing.assert_array_equal(sigma, np.ones((3, 3), np.float32))


def test_uncertainty_head_positive_and_loss_preds_unchanged(router_params):
    rp, rp_unc = router_params
    toks = np.arange(1, 33, dtype=np.int32)[None].repeat(3, axis=0)
    sigma = np.asarray(predict_uncertainty(rp_unc, RC, {"tokens": toks}))
    assert sigma.shape == (3, 3) and (sigma > 0).all()
    a = np.asarray(predict_losses(rp, RC, {"tokens": toks}))
    b = np.asarray(predict_losses(rp_unc, RC, {"tokens": toks}))
    np.testing.assert_array_equal(a, b)          # heads shared by reference


def test_calibrate_uncertainty_learns_residual_scale():
    """The calibrated head must track the frozen router's actual
    residuals far better than the untrained head it starts from."""
    rp, _ = init_router(jax.random.PRNGKey(0), RC)
    rng = np.random.default_rng(0)
    toks = rng.integers(1, 64, size=(96, 32)).astype(np.int32)
    target = np.asarray(
        predict_losses(rp, RC, {"tokens": toks}))
    # synthetic ground truth: router is off by a known per-expert bias
    bias = np.array([0.05, 0.6, 2.0], np.float32)
    target = target + bias[None, :]
    cal = calibrate_uncertainty(rp, RC, toks, target, steps=400, seed=1)
    assert "unc" not in rp                       # original untouched
    sigma = np.asarray(predict_uncertainty(cal, RC, {"tokens": toks}))
    err = np.abs(sigma.mean(0) - bias)
    assert (err < 0.25 * np.maximum(bias, 0.2)).all(), (sigma.mean(0), bias)
    # loss predictions are bit-identical after calibration
    np.testing.assert_array_equal(
        np.asarray(predict_losses(rp, RC, {"tokens": toks})),
        np.asarray(predict_losses(cal, RC, {"tokens": toks})))


# ---------------------------------------------------------- cache layer


def test_cache_key_distinguishes_confidence_threshold():
    toks = np.arange(32, dtype=np.int32)
    k0 = DecisionCache.key(toks, {}, ["size"], 0.0)
    k1 = DecisionCache.key(toks, {}, ["size"], 0.7)
    assert k0 != k1
    cache = DecisionCache(capacity=4)
    cache.put(k0, np.zeros(3), 0, 0, 1.0)
    cache.put(k1, np.zeros(3), 2, 2, 0.4)
    assert cache.get(k0)[1:] == (0, 0, 1.0)
    assert cache.get(k1)[1:] == (2, 2, 0.4)


def test_cached_cascade_verdict_is_exact(tiny_library, router_params):
    """A repeated prompt under the same threshold must return the same
    post-cascade expert, depth and confidence, flagged as cached."""
    _, rp_unc = router_params
    eng = _engine(tiny_library, rp_unc, Clock())
    for r in _requests(6, seed=4, min_confidence=0.99):
        eng.submit(r)
    first = {r.uid: r for r in eng.run()}
    for r in _requests(6, seed=4, min_confidence=0.99):
        eng.submit(r)
    second = {r.uid: r for r in eng.run()}
    assert eng.stats.cache_hits == 6
    for uid, res in second.items():
        assert res.cached and not first[uid].cached
        assert res.expert == first[uid].expert
        assert res.cascade_depth == first[uid].cascade_depth
        assert res.confidence == first[uid].confidence


# --------------------------------------------------------- engine layer


def test_high_threshold_escalates_to_larger_experts(tiny_library,
                                                    router_params):
    """With a strong size flag everything routes small; an unmeetable
    confidence floor must climb the ladder instead, bounded by depth."""
    _, rp_unc = router_params
    sizes = {e.name: e.n_params for e in tiny_library.experts}
    clock = Clock()
    base = _engine(tiny_library, rp_unc, clock)
    for r in _requests(8, seed=2):
        r.lambdas = {"size": 50.0}
        base.submit(r)
    single = base.run()
    assert all(r.expert == "small" for r in single)

    # confidence is strictly below 1, so a threshold of 1.0 always abstains
    casc = _engine(tiny_library, rp_unc, clock, cascade_max_depth=1)
    for r in _requests(8, seed=2, min_confidence=1.0):
        r.lambdas = {"size": 50.0}
        casc.submit(r)
    out = casc.run()
    assert all(r.cascade_depth == 1 for r in out)      # bounded by max depth
    assert all(sizes[r.expert] > sizes["small"] for r in out)
    assert casc.stats.escalations == 8
    assert dict(casc.stats.cascade_depth_hist) == {1: 8}
    assert 1 in casc.stats.tier_latency_percentiles()


def test_escalation_rides_escalation_lanes_in_serve(tiny_library,
                                                    router_params):
    _, rp_unc = router_params
    clock = Clock()
    eng = _engine(tiny_library, rp_unc, clock, max_wait_s=1e9,
                  lane_target=4, cascade_max_depth=2)
    reqs = _requests(9, seed=5, min_confidence=1.0)
    for r in reqs:
        r.lambdas = {"size": 50.0}          # first pick is always "small"
    results = list(eng.serve(iter(reqs)))
    assert sorted(r.uid for r in results) == list(range(9))
    assert eng.stats.escalations == 9
    # router-preferred escalation may reach the ladder top in one jump
    assert all(1 <= r.cascade_depth <= 2 for r in results)
    assert any(name.endswith("@esc") for name in eng.stats.lane_peaks)
    summary = eng.stats.summary()["cascade"]
    assert summary["escalations"] == 9
    assert sum(summary["depth_hist"].values()) == 9


# ------------------------------------------------- single-shot parity


def _result_key(r):
    d = dataclasses.asdict(r)
    d["pred_losses"] = d["pred_losses"].tobytes()
    d["predictions"] = d["predictions"].tobytes()
    return d


@pytest.mark.parametrize("discipline", ["run", "serve"])
def test_min_confidence_zero_matches_pre_cascade_engine(
        tiny_library, router_params, discipline):
    """min_confidence=0 is the PR 2 engine, bit-for-bit: identical
    choices, Results and EngineStats whether the router has an
    uncertainty head or not."""
    rp, rp_unc = router_params
    outs, stats = [], []
    for params in (rp, rp_unc):
        clock = Clock()
        eng = _engine(tiny_library, params, clock, lane_target=4,
                      max_wait_s=1e9)
        reqs = _requests(21, seed=7)
        if discipline == "run":
            for r in reqs:
                eng.submit(r)
            out = eng.run()
        else:
            out = list(eng.serve(iter(reqs)))
        outs.append(sorted(out, key=lambda r: r.uid))
        stats.append(eng.stats.summary())
    for a, b in zip(*outs):
        assert _result_key(a) == _result_key(b)
        assert a.cascade_depth == 0 and a.confidence == 1.0
    assert stats[0] == stats[1]
    assert stats[0]["cascade"]["escalations"] == 0
    assert stats[0]["cascade"]["depth_hist"] == {0: 21}
