"""Crash-safety harness for the persistent decision-cache tier.

The contract under test (docstring of ``repro.serving.kvstore``): a
process killed at ANY byte offset of the segment log loses at most the
record being written.  Recovery replays the intact prefix, quarantines
the torn tail to a sidecar (never served, never fatal), and truncates
the log back to the last good record boundary.

Two sweeps enforce it exhaustively at small scale:

* truncation sweep — write a known log, chop it at every byte offset,
  reload, and assert the recovered store is exactly the consistent
  prefix of the operation sequence (no torn record ever surfaces);
* fault-injection sweep — ``fail_after_bytes`` cuts an append after
  every possible byte count, which is the torn-tail shape a real
  ``kill -9`` leaves behind, and recovery must behave identically.
"""

import os

import pytest

from repro.serving.kvstore import (DiskKVStore, MemoryKVStore,
                                   SimulatedCrash, _frame)


def _ops(n=6):
    """A small op sequence with overwrites and a delete mixed in."""
    ops = []
    for i in range(n):
        ops.append(("set", b"k%d" % (i % 4), b"v%d" % i))
    ops.append(("del", b"k1", b""))
    ops.append(("set", b"k9", b"x" * 37))
    return ops


def _apply(store, ops):
    for op, k, v in ops:
        if op == "set":
            store.set(k, v)
        else:
            store.delete(k)


def _oracle(ops):
    d = {}
    for op, k, v in ops:
        if op == "set":
            d[k] = v
        else:
            d.pop(k, None)
    return d


def _record_boundaries(ops):
    """Byte offsets at which each framed record of ``ops`` ends."""
    off, ends = 0, [0]
    d = {}
    for op, k, v in ops:
        if op == "del" and k not in d:
            continue                      # delete of a missing key: no record
        d[k] = v if op == "set" else d.pop(k, None)
        rec = _frame(0 if op == "set" else 1, k, v)
        off += len(rec)
        ends.append(off)
    return ends


def test_round_trip_and_restart(tmp_path):
    s = DiskKVStore(str(tmp_path))
    _apply(s, _ops())
    want = _oracle(_ops())
    assert {k: s.get(k) for k in s.keys()} == want
    s.close()
    s2 = DiskKVStore(str(tmp_path))
    assert {k: s2.get(k) for k in s2.keys()} == want
    assert s2.quarantined_bytes == 0
    s2.close()


def test_truncation_at_every_byte_recovers_consistent_prefix(tmp_path):
    ops = _ops()
    s = DiskKVStore(str(tmp_path / "w"))
    _apply(s, ops)
    s.close()
    log = (tmp_path / "w" / "segments.log").read_bytes()
    ends = _record_boundaries(ops)
    assert ends[-1] == len(log)           # framing model matches the file
    for cut in range(len(log) + 1):
        d = tmp_path / ("cut%d" % cut)
        d.mkdir()
        (d / "segments.log").write_bytes(log[:cut])
        r = DiskKVStore(str(d))
        # recovered state == replay of the longest whole-record prefix
        n_good = max(i for i, e in enumerate(ends) if e <= cut)
        prefix_ends = ends[n_good]
        want = {}
        applied = 0
        for op, k, v in ops:
            if op == "del" and k not in want:
                continue
            if applied == n_good:
                break
            if op == "set":
                want[k] = v
            else:
                want.pop(k, None)
            applied += 1
        assert {k: r.get(k) for k in r.keys()} == want, f"cut={cut}"
        # torn tail quarantined, log truncated to the good boundary
        assert r.quarantined_bytes == cut - prefix_ends
        assert os.path.getsize(r.path) == prefix_ends
        if cut > prefix_ends:
            assert (d / f"quarantine-{prefix_ends}.bin").exists()
        r.close()


def test_corrupt_middle_byte_stops_replay_without_crashing(tmp_path):
    ops = _ops()
    s = DiskKVStore(str(tmp_path / "w"))
    _apply(s, ops)
    s.close()
    log = bytearray((tmp_path / "w" / "segments.log").read_bytes())
    log[len(log) // 2] ^= 0xFF            # flip one byte mid-log
    d = tmp_path / "bad"
    d.mkdir()
    (d / "segments.log").write_bytes(bytes(log))
    r = DiskKVStore(str(d))               # must not raise
    assert r.quarantined_bytes > 0
    # everything it does serve is a value some prefix of ops produced
    seen = {}
    legal = [dict(seen)]
    for op, k, v in ops:
        if op == "set":
            seen[k] = v
        else:
            seen.pop(k, None)
        legal.append(dict(seen))
    assert {k: r.get(k) for k in r.keys()} in legal
    r.close()


def test_fault_injection_at_every_offset(tmp_path):
    base = _ops()
    tail_key, tail_value = b"crashkey", b"crashvalue" * 3
    rec_len = len(_frame(0, tail_key, tail_value))
    for cut in range(rec_len):
        d = tmp_path / ("crash%d" % cut)
        s = DiskKVStore(str(d))
        _apply(s, base)
        s.flush()
        s.fail_after_bytes = cut
        with pytest.raises(SimulatedCrash):
            s.set(tail_key, tail_value)
        s._fh.close()                     # the "process" is gone
        r = DiskKVStore(str(d))
        want = _oracle(base)              # torn record never surfaces
        assert {k: r.get(k) for k in r.keys()} == want, f"cut={cut}"
        assert r.get(tail_key) is None
        assert r.quarantined_bytes == cut
        r.close()
    # a crash after the full record was written keeps the record
    d = tmp_path / "crash_full"
    s = DiskKVStore(str(d))
    _apply(s, base)
    s.fail_after_bytes = rec_len
    with pytest.raises(SimulatedCrash):
        s.set(tail_key, tail_value)
    s._fh.close()
    r = DiskKVStore(str(d))
    assert r.get(tail_key) == tail_value
    r.close()


def test_compaction_round_trip(tmp_path):
    s = DiskKVStore(str(tmp_path), compact_ratio=0.01)
    for i in range(200):                  # heavy overwrite churn
        s.set(b"hot", b"v%d" % i)
        s.set(b"k%d" % (i % 8), b"w%d" % i)
    live = {k: s.get(k) for k in s.keys()}
    s.compact()
    assert {k: s.get(k) for k in s.keys()} == live
    size = os.path.getsize(s.path)        # compacted log is near-minimal
    s.close()
    r = DiskKVStore(str(tmp_path))
    assert {k: r.get(k) for k in r.keys()} == live
    assert os.path.getsize(r.path) == size
    r.close()


def test_auto_compaction_bounds_log_size(tmp_path):
    s = DiskKVStore(str(tmp_path), compact_ratio=0.5)
    for i in range(500):
        s.set(b"only-key", os.urandom(64))
    s.flush()
    # one live record plus bounded slack, not 500 records of history
    assert os.path.getsize(s.path) < 500 * 64 / 2
    assert s.get(b"only-key") is not None
    s.close()


def test_memory_store_contract():
    m = MemoryKVStore()
    m.set(b"a", b"1")
    m.set(b"a", b"2")
    m.set(b"b", b"3")
    m.delete(b"a")
    m.delete(b"missing")
    m.flush()
    assert m.get(b"a") is None and m.get(b"b") == b"3"
    assert m.keys() == [b"b"] and len(m) == 1
