"""Staged pipeline (Route -> Cascade -> Execute -> Feedback) and the
online-adaptation subsystem.

The centerpiece is the behaviour-preservation contract of the PR-4
refactor: with adaptation off (``adapt_every=0``, the default) the
staged pipeline must reproduce the previous engine's hard-wired
route->cascade->execute flow *bit-for-bit* — identical expert choices,
identical Result fields, identical EngineStats — on the 256-request
mixed-flag workload, under both disciplines, with and without cascade
traffic.  The reference implementation below is a line-for-line copy of
the pre-pipeline orchestration (PR 3 ``_route_admitted`` + ``run`` +
``serve``) driven over the same engine primitives, so the comparison is
environment-independent: any behavioural drift introduced by the stage
split shows up as a hard mismatch.

The adaptation tests cover the replay buffer, the jit'd incremental
update (shadow weights, head-only scope, EMA damping), the version
bump + cache invalidation on swap (no stale-verdict hits), and the
engine-level feedback cadence.  Deliberately hypothesis-free so the
whole module runs without the optional property-testing dep.
"""

import dataclasses
import itertools

import jax
import numpy as np
import pytest

from repro.core.router import (RouterConfig, VersionedParams, init_router,
                               predict_losses)
from repro.core.training import (make_router_update_step,
                                 router_prediction_error)
from repro.data.batching import mlm_batch
from repro.serving import (DecisionCache, ExpertScheduler, ReplayBuffer,
                           Request, TryageEngine)
from repro.serving.pipeline import RouteContext

RC = RouterConfig(n_models=3, vocab_size=64, num_layers=1, d_model=32,
                  num_heads=2, d_ff=64)


class Clock:
    def __init__(self, t=1.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture(scope="module")
def router_params():
    rp, _ = init_router(jax.random.PRNGKey(9), RC)
    return rp


def _requests(n, seed=0, min_confidence=0.0, n_unique=None):
    """Mixed-flag MLM workload; the tail repeats earlier prompts +
    lambdas so the decision cache sees production-shaped traffic."""
    n_unique = n if n_unique is None else n_unique
    rng = np.random.default_rng(seed)
    toks = rng.integers(4, 64, size=(n_unique, 32)).astype(np.int32)
    mb = mlm_batch(toks, rng, 0.2, 64)
    mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]
    return [Request(uid=i, tokens=mb["tokens"][i % n_unique],
                    targets=mb["targets"][i % n_unique],
                    mask=mb["mask"][i % n_unique],
                    lambdas=mix[i % len(mix)],
                    min_confidence=min_confidence)
            for i in range(n)]


def _engine(library, params, clock, **kw):
    from repro.core.objective import recency_constraint, size_constraint
    cons = [size_constraint(library), recency_constraint(library)]
    kw.setdefault("max_batch", 32)
    return TryageEngine(library, params, RC, cons, now_fn=clock, **kw)


# ------------------------------------------------- PR 3 reference flow
#
# A line-for-line copy of the pre-pipeline engine's orchestration: the
# hard-wired _route_admitted (cache probe -> score misses -> cascade ->
# insert) plus the run()/serve() drive loops, expressed over the same
# engine primitives the stages use.  This is the behaviour the staged
# pipeline must reproduce bit-for-bit when adaptation is off.


def _pr3_route_admitted(eng, reqs):
    B = len(reqs)
    if eng.cache is None:
        pred, choice = eng._score_batch(reqs)
        choice, depth, conf = eng._cascade(reqs, pred, choice)
        return pred, choice, np.zeros(B, bool), depth, conf
    pred = np.zeros((B, eng.rc.n_models), np.float32)
    choice = np.zeros(B, np.int64)
    cached = np.zeros(B, bool)
    depth = np.zeros(B, np.int64)
    conf = np.ones(B, np.float64)
    keys = [DecisionCache.key(r.tokens, r.lambdas, eng._cnames,
                              r.min_confidence, eng.router_version)
            for r in reqs]
    misses = []
    for i, key in enumerate(keys):
        hit = eng.cache.get(key)
        if hit is None:
            misses.append(i)
        else:
            pred[i], choice[i], depth[i], conf[i] = hit
            cached[i] = True
            # tier attribution is pure telemetry added with the cache
            # stack; the exact LRU is tier "t1" in both flows
            eng.stats.cache_tier_hits["t1"] += 1
    if misses:
        miss_reqs = [reqs[i] for i in misses]
        mpred, mchoice = eng._score_batch(miss_reqs)
        mchoice, mdepth, mconf = eng._cascade(miss_reqs, mpred, mchoice)
        for j, i in enumerate(misses):
            pred[i] = mpred[j]
            choice[i] = mchoice[j]
            depth[i] = mdepth[j]
            conf[i] = mconf[j]
            eng.cache.put(keys[i], mpred[j], mchoice[j],
                          int(mdepth[j]), float(mconf[j]))
    eng.stats.cache_hits += B - len(misses)
    eng.stats.cache_misses += len(misses)
    return pred, choice, cached, depth, conf


def _pr3_run(eng):
    from collections import defaultdict

    from repro.serving.scheduler import LaneEntry
    results = []
    while eng.queue:
        batch, eng.queue = (eng.queue[:eng.max_batch],
                            eng.queue[eng.max_batch:])
        pred, choice, cached, depth, conf = _pr3_route_admitted(eng, batch)
        by_expert = defaultdict(list)
        for i, c in enumerate(choice):
            by_expert[int(c)].append(i)
        for mi, idxs in sorted(by_expert.items()):
            entries = [LaneEntry(batch[i], pred[i], i, bool(cached[i]),
                                 int(depth[i]), float(conf[i]))
                       for i in idxs]
            results.extend(eng._execute(mi, entries, "fifo"))
    return results


def _pr3_serve(eng, request_iter):
    sched = ExpertScheduler(len(eng.library), eng.lane_target,
                            eng.max_wait_s)
    admitted = []

    def _admit():
        pred, choice, cached, depth, conf = _pr3_route_admitted(
            eng, admitted)
        for i, r in enumerate(admitted):
            sched.push(int(choice[i]), r, pred[i], bool(cached[i]),
                       int(depth[i]), float(conf[i]))
        admitted.clear()

    if eng.queue:
        queued, eng.queue = eng.queue, []
        request_iter = itertools.chain(queued, request_iter)
    for item in request_iter:
        if item is not None:
            if item.arrival is None:
                item.arrival = eng._now()
            admitted.append(item)
        if admitted and (len(admitted) >= eng.max_batch
                         or (eng._now() - admitted[0].arrival
                             >= 0.5 * eng.max_wait_s)):
            _admit()
        for mi, entries, reason in sched.pop_ready(eng._now()):
            yield from eng._execute(mi, entries, reason)
    if admitted:
        _admit()
    for mi, entries, reason in sched.drain():
        yield from eng._execute(mi, entries, reason)
    for mi, peak in sched.peaks().items():
        name = eng.library[mi].name
        eng.stats.lane_peaks[name] = max(
            eng.stats.lane_peaks.get(name, 0), peak)
    for mi, peak in sched.esc_peaks().items():
        name = eng.library[mi].name + "@esc"
        eng.stats.lane_peaks[name] = max(
            eng.stats.lane_peaks.get(name, 0), peak)


def _result_key(r):
    d = dataclasses.asdict(r)
    d["pred_losses"] = d["pred_losses"].tobytes()
    d["predictions"] = d["predictions"].tobytes()
    return d


@pytest.mark.parametrize("discipline,min_conf", [
    ("run", 0.0), ("serve", 0.0), ("run", 0.99), ("serve", 0.99)])
def test_pipeline_matches_pr3_flow_bit_for_bit(tiny_library, router_params,
                                               discipline, min_conf):
    """The staged pipeline (adaptation off) reproduces the pre-pipeline
    engine on the 256-request mixed-flag workload: identical choices,
    Results and EngineStats, cache hits included."""
    outs, stats = [], []
    for flow in ("pipeline", "pr3"):
        clock = Clock()
        eng = _engine(tiny_library, router_params, clock, lane_target=8,
                      max_wait_s=1e9)
        reqs = _requests(256, seed=7, min_confidence=min_conf, n_unique=192)
        if discipline == "run":
            for r in reqs:
                eng.submit(r)
            out = eng.run() if flow == "pipeline" else _pr3_run(eng)
        else:
            it = iter(reqs)
            out = list(eng.serve(it) if flow == "pipeline"
                       else _pr3_serve(eng, it))
        assert len(out) == 256
        outs.append(sorted(out, key=lambda r: r.uid))
        stats.append(eng.stats.summary())
    for a, b in zip(*outs):
        assert _result_key(a) == _result_key(b)
    assert stats[0] == stats[1]
    assert stats[0]["cache"]["hits"] == 64          # 64/256 repeats
    assert stats[0]["adaptation"]["updates"] == 0
    assert stats[0]["adaptation"]["router_version"] == 0
    # feedback is collected (for telemetry) even with a frozen router:
    # one sample per request whose loss was actually measured
    measured = sum(1 for r in outs[0] if r.loss is not None)
    assert stats[0]["adaptation"]["feedback_events"] == measured > 0


def test_admit_context_contract(tiny_library, router_params):
    """pipeline.admit fills every RouteContext field with dense arrays
    of the right shape/dtype."""
    eng = _engine(tiny_library, router_params, Clock())
    reqs = _requests(5, seed=3)
    ctx = eng.pipeline.admit(reqs)
    assert isinstance(ctx, RouteContext)
    assert ctx.pred.shape == (5, 3) and ctx.pred.dtype == np.float32
    assert ctx.choice.shape == (5,) and ctx.choice.dtype == np.int64
    assert ctx.cached.shape == (5,) and ctx.cached.dtype == bool
    assert ctx.depth.shape == (5,) and ctx.confidence.shape == (5,)
    assert ctx.miss_idx == list(range(5))           # cold cache
    assert len(ctx.keys) == 5
    # second admit of the same requests: all hits, no fresh rows
    ctx2 = eng.pipeline.admit(_requests(5, seed=3))
    assert ctx2.miss_idx == [] and ctx2.cached.all()
    np.testing.assert_array_equal(ctx.choice, ctx2.choice)


# ------------------------------------------------------- replay buffer


def test_replay_buffer_bounded_ring():
    buf = ReplayBuffer(capacity=4)
    for i in range(6):
        buf.add(np.full(8, i, np.int32), i % 3, float(i))
    assert len(buf) == 4 and buf.seen == 6
    toks, eidx, loss = buf.sample(16, np.random.default_rng(0))
    assert toks.shape == (16, 8) and eidx.shape == (16,)
    assert loss.shape == (16,) and loss.dtype == np.float32
    # oldest two samples (0, 1) were overwritten by 4, 5
    assert set(toks[:, 0].tolist()) <= {2, 3, 4, 5}


def test_replay_buffer_drops_shape_mismatch():
    """Mixed-length traffic must not crash serving: off-shape samples
    are dropped and counted, never raised."""
    buf = ReplayBuffer(capacity=4)
    assert buf.add(np.zeros(8, np.int32), 0, 1.0)
    assert not buf.add(np.zeros(16, np.int32), 0, 1.0)
    assert len(buf) == 1 and buf.seen == 1 and buf.dropped == 1


def test_engine_rejects_adaptation_without_replay(tiny_library,
                                                  router_params):
    with pytest.raises(ValueError, match="replay"):
        _engine(tiny_library, router_params, Clock(), adapt_every=8,
                replay_cap=0)


def test_replay_buffer_detaches_tokens():
    buf = ReplayBuffer(capacity=4)
    toks = np.arange(8).astype(np.int32)
    buf.add(toks, 0, 1.0)
    toks[:] = -1
    sampled, _, _ = buf.sample(1, np.random.default_rng(0))
    assert (sampled[0] == np.arange(8)).all()


# ------------------------------------------- incremental update step


def test_versioned_params_swap_is_monotone_and_pure(router_params):
    v0 = VersionedParams(router_params, 0)
    v1 = v0.swap({"head": None})
    assert (v0.version, v1.version) == (0, 1)
    assert v0.params is router_params                # old snapshot intact
    assert v1.swap({}).version == 2


def _bandit_batch(params, seed=0, delta=2.0):
    """Feedback batch whose observed losses sit ``delta`` above the
    router's current predictions for the chosen experts."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(4, 64, size=(16, 32)).astype(np.int32)
    pred = np.asarray(predict_losses(params, RC, {"tokens": toks}))
    eidx = rng.integers(0, RC.n_models, size=16).astype(np.int32)
    obs = pred[np.arange(16), eidx] + delta
    return toks, eidx, obs.astype(np.float32)


def test_router_update_step_moves_predictions_toward_observed():
    rp, _ = init_router(jax.random.PRNGKey(0), RC)
    toks, eidx, obs = _bandit_batch(rp)
    err0 = float(router_prediction_error(rp, RC, toks, eidx, obs))
    step = make_router_update_step(RC, lr=0.1, trainable="head")
    p = rp
    for _ in range(25):
        p, loss = step(p, toks, eidx, obs)
    err1 = float(router_prediction_error(p, RC, toks, eidx, obs))
    assert err1 < 0.5 * err0, (err0, err1)
    # shadow weights: the input tree was never mutated
    err_again = float(router_prediction_error(rp, RC, toks, eidx, obs))
    assert err_again == err0


def test_head_only_update_freezes_encoder_and_unc():
    rp, _ = init_router(jax.random.PRNGKey(1), RC, uncertainty=True)
    toks, eidx, obs = _bandit_batch(rp, seed=1)
    step = make_router_update_step(RC, lr=0.1, trainable="head")
    new, _ = step(rp, toks, eidx, obs)
    for leaf_old, leaf_new in zip(jax.tree.leaves(rp["encoder"]),
                                  jax.tree.leaves(new["encoder"])):
        np.testing.assert_array_equal(np.asarray(leaf_old),
                                      np.asarray(leaf_new))
    for leaf_old, leaf_new in zip(jax.tree.leaves(rp["unc"]),
                                  jax.tree.leaves(new["unc"])):
        np.testing.assert_array_equal(np.asarray(leaf_old),
                                      np.asarray(leaf_new))
    assert any((np.asarray(a) != np.asarray(b)).any()
               for a, b in zip(jax.tree.leaves(rp["head"]),
                               jax.tree.leaves(new["head"])))


def test_full_update_adapts_encoder_but_never_unc():
    rp, _ = init_router(jax.random.PRNGKey(2), RC, uncertainty=True)
    toks, eidx, obs = _bandit_batch(rp, seed=2)
    step = make_router_update_step(RC, lr=0.1, trainable="all")
    new, _ = step(rp, toks, eidx, obs)
    assert any((np.asarray(a) != np.asarray(b)).any()
               for a, b in zip(jax.tree.leaves(rp["encoder"]),
                               jax.tree.leaves(new["encoder"])))
    for leaf_old, leaf_new in zip(jax.tree.leaves(rp["unc"]),
                                  jax.tree.leaves(new["unc"])):
        np.testing.assert_array_equal(np.asarray(leaf_old),
                                      np.asarray(leaf_new))


def test_ema_damps_the_step():
    rp, _ = init_router(jax.random.PRNGKey(3), RC)
    toks, eidx, obs = _bandit_batch(rp, seed=3)

    def travel(ema):
        step = make_router_update_step(RC, lr=0.1, ema=ema,
                                       trainable="head")
        new, _ = step(rp, toks, eidx, obs)
        return sum(float(np.abs(np.asarray(a) - np.asarray(b)).sum())
                   for a, b in zip(jax.tree.leaves(rp["head"]),
                                   jax.tree.leaves(new["head"])))

    d_plain, d_damped = travel(0.0), travel(0.75)
    assert 0.0 < d_damped < d_plain
    np.testing.assert_allclose(d_damped, 0.25 * d_plain, rtol=1e-4)


# ------------------------------------- engine-level adaptation loop


def test_engine_adapts_and_bumps_version(tiny_library, router_params):
    clock = Clock()
    eng = _engine(tiny_library, router_params, clock, adapt_every=8,
                  adapt_batch=8, adapt_lr=0.05, replay_cap=64)
    for r in _requests(32, seed=11):
        eng.submit(r)
    eng.run()
    s = eng.stats.summary()["adaptation"]
    assert s["updates"] >= 1
    assert s["router_version"] == s["updates"] == eng.router_version
    # one feedback sample per request whose loss was measured (a request
    # can draw an all-zero MLM mask and contribute nothing)
    assert 24 <= s["feedback_events"] <= 32
    assert s["replay"] == {"len": s["feedback_events"], "cap": 64}
    assert s["pre_err"] > 0.0 and s["post_err"] > 0.0


def test_version_bump_invalidates_cache_no_stale_hits(tiny_library,
                                                      router_params):
    """After every router swap, repeated prompts must MISS and re-score:
    a verdict scored by a superseded router version can never hit."""
    clock = Clock()
    eng = _engine(tiny_library, router_params, clock, adapt_every=8,
                  adapt_batch=8, adapt_lr=0.05, replay_cap=64)
    reqs = _requests(16, seed=13)
    for r in reqs:
        eng.submit(r)
    eng.run()
    v1 = eng.router_version
    assert v1 >= 1 and eng.stats.cache_hits == 0
    assert len(eng.cache) == 0                      # cleared on swap
    # identical prompts again: all fresh scores against the new router
    for r in _requests(16, seed=13):
        eng.submit(r)
    out = eng.run()
    assert eng.stats.cache_hits == 0
    assert not any(r.cached for r in out)
    assert eng.router_version > v1                  # kept adapting
    # and the key itself separates versions
    toks = np.arange(32, dtype=np.int32)
    assert (DecisionCache.key(toks, {}, ["size"], 0.0, 0)
            != DecisionCache.key(toks, {}, ["size"], 0.0, 1))


def test_frozen_engine_version_pinned_and_cache_warm(tiny_library,
                                                     router_params):
    """adapt_every=0: no updates, version stays 0, repeats hit."""
    eng = _engine(tiny_library, router_params, Clock())
    for r in _requests(16, seed=17):
        eng.submit(r)
    eng.run()
    for r in _requests(16, seed=17):
        eng.submit(r)
    out = eng.run()
    assert eng.router_version == 0
    assert eng.stats.adapt_updates == 0
    assert eng.stats.cache_hits == 16
    assert all(r.cached for r in out)


def test_adaptation_tracks_observed_loss_scale(tiny_library,
                                               router_params):
    """End-to-end drift-in-miniature: the untrained router predicts
    tiny losses while the (untrained) experts' observed MLM losses sit
    near ln(vocab) — feedback must pull the served router's predictions
    up toward the observed scale, shrinking the replay prediction
    error, while a frozen engine's parameters never move."""
    probe = np.stack([r.tokens for r in _requests(16, seed=200)])
    pred0 = np.asarray(predict_losses(router_params, RC,
                                      {"tokens": probe}))

    eng = _engine(tiny_library, router_params, Clock(), adapt_every=4,
                  adapt_batch=16, adapt_lr=0.2, replay_cap=64,
                  max_batch=8)
    first_err = None
    for round_ in range(6):
        for r in _requests(16, seed=100 + round_):
            r.lambdas = {}
            eng.submit(r)
        eng.run()
        if first_err is None and eng.stats.adapt_updates:
            first_err = eng.stats.adapt_pre_err
    assert eng.stats.adapt_updates >= 6
    assert eng.router_params is not router_params   # swapped snapshots
    pred1 = np.asarray(predict_losses(eng.router_params, RC,
                                      {"tokens": probe}))
    assert pred1.mean() > pred0.mean() + 0.5        # pulled up
    assert eng.stats.adapt_post_err < first_err     # error shrinking

    frozen = _engine(tiny_library, router_params, Clock(), max_batch=8)
    for r in _requests(16, seed=100):
        frozen.submit(r)
    frozen.run()
    assert frozen.router_params is router_params    # never swapped
