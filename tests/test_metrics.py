"""Metrics export: exposition-format correctness of ``render()``, the
registry contract, and the scrape endpoint round-trip.

``render`` duck-types its stats argument, so most tests run on a plain
fake; one test renders a real ``EngineStats`` to catch field renames.
"""

import urllib.error
import urllib.request

import pytest

from repro.serving.metrics import (CONTENT_TYPE, LATENCY_BUCKETS, METRICS,
                                   MetricsServer, metric_names, render)


class FakeStats:
    """The attribute surface ``render`` reads, with overridable values."""

    def __init__(self, **kw):
        self.served = 7
        self.per_expert = {"small": 4, "big": 3}
        self.admitted = 9
        self.shed = 2
        self.shed_by_priority = {0: 2}
        self.failed = 1
        self.cache_hits = 3
        self.cache_misses = 4
        self.cache_tier_hits = {"t1": 2, "t2": 1}
        self.cache_revalidations = 1
        self.cache_revalidation_rejects = 0
        self.cache_key_dropped_lambda = 0
        self.escalations = 1
        self.cascade_depth_hist = {1: 1}
        self.spec_launched = 4
        self.spec_hits = 2
        self.spec_cancelled = 1
        self.spec_wasted = 1
        self.spec_wasted_tokens = 32
        self.fallbacks = 2
        self.fallback_depth_hist = {1: 2}
        self.degraded = 0
        self.reroutes = 1
        self.expert_failures = {"big": 1}
        self.flushes = {"target": 2, "deadline": 1}
        self.padded_rows = 5
        self.total_flops = 1.5e9
        self.router_time_s = 0.25
        self.expert_time_s = 1.5
        self.adapt_updates = 0
        self.feedback_events = 7
        self.router_version = 1
        self.replay_len = 7
        self.sessions = 2
        self.admission_queue_peak = 3
        self.latencies = [0.002, 0.004, 0.03, 0.2]
        for k, v in kw.items():
            setattr(self, k, v)


class FakeHealth:
    def __init__(self, n):
        self.n = n
        self.states = [type("S", (), {"depth_ewma": 1.5 * i,
                                      "latency_ewma_s": 0.01 * i,
                                      "failure_ewma": 0.0})()
                       for i in range(n)]

    def healthy(self, i):
        return i != 1

    def available(self, i):
        return i == 0


def _families(text):
    """Parse exposition text into {family: (mtype, [sample lines])},
    asserting the format invariants along the way: HELP then TYPE then
    that family's samples, contiguous, nothing stray."""
    fams, current = {}, None
    lines = text.splitlines()
    assert text.endswith("\n") and lines
    i = 0
    while i < len(lines):
        line = lines[i]
        assert line.startswith("# HELP "), f"expected HELP at: {line!r}"
        name = line.split()[2]
        tline = lines[i + 1]
        assert tline.startswith(f"# TYPE {name} "), tline
        mtype = tline.split()[3]
        assert mtype in ("counter", "gauge", "histogram")
        i += 2
        samples = []
        while i < len(lines) and not lines[i].startswith("#"):
            base = lines[i].split("{")[0].split(" ")[0]
            if mtype == "histogram":
                assert base in (name + "_bucket", name + "_sum",
                                name + "_count"), lines[i]
            else:
                assert base == name, lines[i]
            samples.append(lines[i])
            i += 1
        assert name not in fams, f"duplicate family {name}"
        fams[name] = (mtype, samples)
    return fams


def test_registry_names_unique_and_prefixed():
    names = metric_names()
    assert len(names) == len(set(names)) == len(METRICS)
    assert all(n.startswith("tryage_") for n in names)
    for m in METRICS:
        assert (m.mtype == "counter") == m.name.endswith("_total")


def test_render_covers_whole_registry_in_order():
    fams = _families(render(FakeStats()))
    assert list(fams) == metric_names()
    for m in METRICS:
        assert fams[m.name][0] == m.mtype


def test_scalar_and_labelled_samples():
    fams = _families(render(FakeStats()))
    assert fams["tryage_requests_served_total"][1] == \
        ["tryage_requests_served_total 7"]
    by_expert = fams["tryage_requests_by_expert_total"][1]
    assert 'tryage_requests_by_expert_total{expert="big"} 3' in by_expert
    assert 'tryage_requests_by_expert_total{expert="small"} 4' in by_expert
    assert by_expert == sorted(by_expert)      # deterministic label order
    assert fams["tryage_flushes_total"][1] == \
        ['tryage_flushes_total{reason="deadline"} 1',
         'tryage_flushes_total{reason="target"} 2']


def test_label_values_escaped():
    stats = FakeStats(per_expert={'we"ird\\name': 1})
    out = render(stats)
    assert r'{expert="we\"ird\\name"} 1' in out


def test_histogram_buckets_monotone_and_consistent():
    lat = [0.002, 0.004, 0.03, 0.2]
    fams = _families(render(FakeStats(latencies=lat)))
    samples = fams["tryage_request_latency_seconds"][1]
    buckets = [s for s in samples if "_bucket" in s]
    assert len(buckets) == len(LATENCY_BUCKETS) + 1
    counts = [float(s.rsplit(" ", 1)[1]) for s in buckets]
    assert counts == sorted(counts)            # cumulative => monotone
    assert counts[-1] == len(lat)              # +Inf holds everything
    # spot-check: two latencies at or under 5ms
    assert 'le="0.005"} 2' in buckets[1]
    total = [s for s in samples if s.startswith(
        "tryage_request_latency_seconds_count")][0]
    assert total.endswith(f" {len(lat)}")
    ssum = [s for s in samples if s.startswith(
        "tryage_request_latency_seconds_sum")][0]
    assert float(ssum.rsplit(" ", 1)[1]) == pytest.approx(sum(lat))


def test_histogram_empty_window():
    fams = _families(render(FakeStats(latencies=[])))
    samples = fams["tryage_request_latency_seconds"][1]
    for s in samples:
        assert s.endswith(" 0")


def test_health_series_headers_only_without_health():
    fams = _families(render(FakeStats()))
    for name in ("tryage_expert_healthy", "tryage_expert_available",
                 "tryage_expert_failure_ewma"):
        assert fams[name][1] == []             # present but empty


def test_health_series_with_names():
    fams = _families(render(FakeStats(), FakeHealth(3), ["s", "m", "b"]))
    assert fams["tryage_expert_healthy"][1] == \
        ['tryage_expert_healthy{expert="s"} 1',
         'tryage_expert_healthy{expert="m"} 0',
         'tryage_expert_healthy{expert="b"} 1']
    assert fams["tryage_expert_available"][1][0].endswith(" 1")
    assert fams["tryage_expert_available"][1][1].endswith(" 0")
    assert fams["tryage_expert_lane_depth_ewma"][1] == \
        ['tryage_expert_lane_depth_ewma{expert="s"} 0',
         'tryage_expert_lane_depth_ewma{expert="m"} 1.5',
         'tryage_expert_lane_depth_ewma{expert="b"} 3']


def test_render_real_engine_stats():
    """Field-rename canary: render a real (default) EngineStats."""
    from repro.serving.engine import EngineStats
    fams = _families(render(EngineStats()))
    assert list(fams) == metric_names()
    assert fams["tryage_requests_served_total"][1] == \
        ["tryage_requests_served_total 0"]


# ------------------------------------------------------ scrape endpoint


def test_metrics_server_round_trip():
    stats = FakeStats()
    srv = MetricsServer(0, lambda: render(stats)).start()
    try:
        url = f"http://127.0.0.1:{srv.port}/metrics"
        with urllib.request.urlopen(url, timeout=5) as resp:
            assert resp.status == 200
            assert resp.headers["Content-Type"] == CONTENT_TYPE
            body = resp.read().decode("utf-8")
        assert list(_families(body)) == metric_names()
        # a fresh collect() per scrape: mutate and re-read
        stats.served = 99
        with urllib.request.urlopen(url, timeout=5) as resp:
            assert "tryage_requests_served_total 99" in \
                resp.read().decode("utf-8")
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(
                f"http://127.0.0.1:{srv.port}/nope", timeout=5)
        assert ei.value.code == 404
    finally:
        srv.stop()
