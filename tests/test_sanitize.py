"""Checkify sanitizer smoke tests: inject a NaN and an out-of-range
value into each of the three kernels under the sanitizer and assert the
error surfaces with the kernel's name; with the switch off, the same
calls must run the untouched fast path.

OOB injection strategy per kernel (documented because each surface
differs): flash_attention and mlstm_scan take the bad value through the
public API (a window wider than the sequence; a stabilizer state beyond
the exp range); router_score's choice is produced *by* the kernel, so
the test simulates a miscompiled kernel by monkeypatching
``router_score_fused`` to emit an out-of-range expert index.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import sanitize
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.mlstm_scan.ops import mlstm_chunkwise
from repro.kernels.router_score import ops as rs_ops


@pytest.fixture
def sanitized(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    sanitize.set_sanitize(True)
    yield
    sanitize.set_sanitize(None)


def _flash_args():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    return q, k, v


def _router_args():
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    emb = jax.random.normal(ks[0], (8, 16))
    head = {"w1": jax.random.normal(ks[1], (16, 8)) * 0.1,
            "b1": jax.random.normal(ks[2], (8,)) * 0.1,
            "w2": jax.random.normal(ks[3], (8, 4)) * 0.1,
            "b2": jax.random.normal(ks[4], (4,)) * 0.1}
    cv = np.asarray(jax.random.uniform(ks[5], (1, 4)), np.float32)
    lam = np.zeros((8, 1), np.float32)
    return emb, head, cv, lam


def _mlstm_args():
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    B, S, H, dh = 1, 64, 1, 16
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 3.0
    st = {"C": jnp.zeros((B, H, dh, dh)), "n": jnp.zeros((B, H, dh)),
          "m": jnp.zeros((B, H))}
    return q, k, v, ig, fg, st


# --------------------------------------------------------------- off

def test_sanitize_off_by_default(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    sanitize.set_sanitize(None)
    assert not sanitize.sanitize_enabled()
    q, k, v = _flash_args()
    qn = q.at[0, 0, 0, 0].set(jnp.nan)
    out = flash_attention(qn, k, v, block_q=64, block_k=64)  # no raise
    assert not bool(jnp.isfinite(out).all())


def test_env_switch(monkeypatch):
    sanitize.set_sanitize(None)
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitize.sanitize_enabled()
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    assert not sanitize.sanitize_enabled()


def test_sanitize_on_keeps_clean_outputs_identical(sanitized):
    q, k, v = _flash_args()
    on = flash_attention(q, k, v, block_q=64, block_k=64)
    sanitize.set_sanitize(False)
    off = flash_attention(q, k, v, block_q=64, block_k=64)
    np.testing.assert_array_equal(np.asarray(on), np.asarray(off))


# ------------------------------------------------------- flash_attention

def test_flash_nan_input_caught(sanitized):
    q, k, v = _flash_args()
    qn = q.at[0, 3, 1, 0].set(jnp.nan)
    with pytest.raises(Exception, match="flash_attention"):
        flash_attention(qn, k, v, block_q=64, block_k=64)


def test_flash_window_oob_caught(sanitized):
    q, k, v = _flash_args()
    with pytest.raises(Exception, match="flash_attention.*window"):
        flash_attention(q, k, v, window=k.shape[1] + 5,
                        block_q=64, block_k=64)


def test_flash_clean_passes(sanitized):
    q, k, v = _flash_args()
    out = flash_attention(q, k, v, window=32, block_q=64, block_k=64)
    assert bool(jnp.isfinite(out).all())


def test_sanitize_skips_checks_under_jit(sanitized):
    """Inside an outer jit the wrapper sees tracers; the concrete guard
    must skip the eager checks instead of crashing the trace."""
    q, k, v = _flash_args()
    f = jax.jit(lambda q, k, v: flash_attention(q, k, v, block_q=64,
                                                block_k=64))
    out = f(q, k, v)
    assert bool(jnp.isfinite(out).all())


# ----------------------------------------------------------- router_score

def test_router_nan_input_caught(sanitized):
    emb, head, cv, lam = _router_args()
    embn = emb.at[0, 0].set(jnp.nan)
    with pytest.raises(Exception, match="router_score"):
        rs_ops.router_route(embn, head, cv, lam, interpret=True)


def test_router_oob_choice_caught(sanitized, monkeypatch):
    emb, head, cv, lam = _router_args()
    real = rs_ops.router_score_fused

    def corrupted(*args, **kwargs):
        pred, choice = real(*args, **kwargs)
        return pred, choice + head["w2"].shape[1]  # miscompiled argmin

    monkeypatch.setattr(rs_ops, "router_score_fused", corrupted)
    with pytest.raises(Exception, match="router_score.*expert choice"):
        rs_ops.router_route(emb, head, cv, lam, interpret=True)


def test_router_clean_passes(sanitized):
    emb, head, cv, lam = _router_args()
    pred, choice = rs_ops.router_route(emb, head, cv, lam, interpret=True)
    assert bool((choice >= 0).all())
    assert bool((choice < head["w2"].shape[1]).all())


# ------------------------------------------------------------- mlstm_scan

def test_mlstm_nan_input_caught(sanitized):
    q, k, v, ig, fg, st = _mlstm_args()
    vn = v.at[0, 5, 0, 3].set(jnp.nan)
    with pytest.raises(Exception, match="mlstm_scan"):
        mlstm_chunkwise(q, k, vn, ig, fg, st, chunk=16)


def test_mlstm_stabilizer_oob_caught(sanitized):
    q, k, v, ig, fg, st = _mlstm_args()
    st = dict(st, m=jnp.full_like(st["m"], 1e5))  # finite but beyond exp range
    with pytest.raises(Exception, match="mlstm_scan.*stabilizer"):
        mlstm_chunkwise(q, k, v, ig, fg, st, chunk=16)


def test_mlstm_clean_passes(sanitized):
    q, k, v, ig, fg, st = _mlstm_args()
    h, st1 = mlstm_chunkwise(q, k, v, ig, fg, st, chunk=16)
    assert bool(jnp.isfinite(h).all())


# ----------------------------------------------- engine integration bits

def test_engine_sanitize_batch_checks():
    """The engine's scored-batch validation: token range host-side,
    pred/choice under checkify (exercised on a stub so the test does not
    need a model library)."""
    from repro.core.router import RouterConfig
    from repro.serving.engine import TryageEngine

    class Stub:
        rc = RouterConfig(n_models=3, vocab_size=16)

    stub = Stub()
    toks = np.array([[1, 2], [3, 4]])
    pred = jnp.ones((2, 3))
    choice = jnp.array([0, 2])
    TryageEngine._sanitize_batch(stub, toks, pred, choice)      # clean
    TryageEngine._sanitize_batch(stub, toks, pred)              # host path
    with pytest.raises(ValueError, match="token id"):
        TryageEngine._sanitize_batch(stub, np.array([[99]]), pred, choice)
    with pytest.raises(Exception, match="router_score"):
        TryageEngine._sanitize_batch(stub, toks,
                                     pred.at[0, 0].set(jnp.nan), choice)
    with pytest.raises(Exception, match="expert choice"):
        TryageEngine._sanitize_batch(stub, toks, pred,
                                     jnp.array([0, 5]))


def test_cache_version_assertion():
    """After a swap every surviving cache entry must carry the live
    router version; a stale entry trips the engine's assertion pass."""
    from repro.core.router import VersionedParams
    from repro.serving.cache import DecisionCache
    from repro.serving.engine import TryageEngine

    class Stub:
        pass

    stub = Stub()
    stub.cache = DecisionCache(capacity=8)
    stub._router = VersionedParams({}, 1)
    TryageEngine._assert_cache_version(stub)       # empty cache: holds
    tok = np.array([1, 2, 3])
    live = DecisionCache.key(tok, {}, [], 0.0, router_version=1)
    stub.cache.put(live, np.zeros(3), 1)
    TryageEngine._assert_cache_version(stub)       # live entries: holds
    stale = DecisionCache.key(tok, {}, [], 0.0, router_version=0)
    stub.cache.put(stale, np.zeros(3), 1)
    assert stub.cache.stale_versions(1) == {0}
    with pytest.raises(AssertionError, match="router version"):
        TryageEngine._assert_cache_version(stub)

    stub.cache = None
    TryageEngine._assert_cache_version(stub)       # cache disabled: no-op
