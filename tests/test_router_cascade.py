"""One-launch cascade decision kernel (``kernels.router_cascade``).

Three parity layers, mirroring the contract every kernel in this repo
carries (the kernel is an optimisation, never a behaviour change):

* kernel vs. the pure-jnp oracle (``ref.py``) across padded-tail batch
  sizes (1, 3, 127, 1000 — every tail shape the launch plan produces);
* the kernel's depth-1 escalation target vs. the host
  ``objective.cascade_choice`` walk, tie-breaks included;
* the fused-cascade engine vs. the staged engine on a mixed-threshold
  workload, under both disciplines — identical choices, depths and
  confidences.

Deliberately hypothesis-free so the module runs without the optional
property-testing dep.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.objective import (cascade_choice, confidence_scores,
                                  recency_constraint, size_constraint)
from repro.core.router import RouterConfig, init_router
from repro.data.batching import mlm_batch
from repro.kernels.router_cascade.kernel import router_score_cascade_fused
from repro.kernels.router_cascade.ref import router_score_cascade_ref
from repro.serving import Request, TryageEngine

RC = RouterConfig(n_models=3, vocab_size=64, num_layers=1, d_model=32,
                  num_heads=2, d_ff=64)


def _workload(seed, B, d=32, hid=16, M=5, nc=2):
    """Random embeddings + both heads + constraints + a random ladder."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 12)
    emb = jax.random.normal(ks[0], (B, d))
    w1 = jax.random.normal(ks[1], (d, hid)) * 0.1
    b1 = jax.random.normal(ks[2], (hid,)) * 0.1
    w2 = jax.random.normal(ks[3], (hid, M)) * 0.1
    b2 = jax.random.normal(ks[4], (M,)) * 0.1
    uw1 = jax.random.normal(ks[5], (d, hid)) * 0.1
    ub1 = jax.random.normal(ks[6], (hid,)) * 0.1
    uw2 = jax.random.normal(ks[7], (hid, M)) * 0.1
    ub2 = jax.random.normal(ks[8], (M,)) * 0.1
    cvals = jax.random.uniform(ks[9], (nc, M))
    lam = jax.random.uniform(ks[10], (B, nc)) * 2
    ladder = jnp.asarray(jax.random.permutation(ks[11], M), jnp.int32)
    return (emb, w1, b1, w2, b2, uw1, ub1, uw2, ub2, cvals, lam, ladder)


# ----------------------------------------------------- kernel vs oracle

@pytest.mark.parametrize("B,block_b", [
    (1, 16),       # single row, tile fully padded
    (3, 16),       # tiny ragged batch
    (37, 16),      # multi-tile ragged tail
    (127, 32),     # 127 % 32 != 0
    (1000, 128),   # serving-scale ragged tail (1000 % 128 != 0)
])
def test_cascade_kernel_vs_ref(B, block_b):
    args = _workload(B, B)
    p1, s1, c1, e1 = router_score_cascade_fused(*args, block_b=block_b)
    p2, s2, c2, e2 = router_score_cascade_ref(*args)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))
    assert np.asarray(s1).min() > 0.0          # sigma floor survived


def test_cascade_kernel_block_size_invariance():
    """Tile geometry must not change any output: same batch under a
    1-tile and a 5-tile launch."""
    args = _workload(3, 37)
    big = router_score_cascade_fused(*args, block_b=1024)   # clamps to 37
    small = router_score_cascade_fused(*args, block_b=8)
    for a, b in zip(big[:2], small[:2]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    for a, b in zip(big[2:], small[2:]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cascade_kernel_pad_rows_do_not_leak():
    """Real rows must be independent of whatever shares their tile: the
    first 7 rows of a 7-row call and of a 29-row call (same weights,
    extra garbage rows appended) must agree."""
    emb, *rest = _workload(5, 29)
    ladder = rest[-1]
    outs_full = router_score_cascade_fused(emb, *rest, block_b=16)
    lam = rest[-2]
    outs_head = router_score_cascade_fused(
        emb[:7], *rest[:-2], lam[:7], ladder, block_b=16)
    for a, b in zip(outs_head[:2], outs_full[:2]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b)[:7],
                                   atol=1e-6)
    for a, b in zip(outs_head[2:], outs_full[2:]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[:7])


def test_escalation_target_matches_host_walk():
    """The kernel's ``esc`` output is the router-preferred depth-1 step
    of ``cascade_choice`` — same target, same tie-break — and echoes
    ``choice`` at the top rung."""
    B, M = 64, 5
    args = _workload(7, B, M=M)
    pred, sigma, choice, esc = (np.asarray(x) for x in
                                router_score_cascade_fused(*args,
                                                           block_b=16))
    cvals = np.asarray(args[9])
    lam = np.asarray(args[10])
    ladder_pos = np.asarray(args[11])
    # order[pos] = expert at that ladder rung (inverse permutation)
    order = [int(i) for i in np.argsort(ladder_pos)]
    conf = confidence_scores(sigma)
    scores = pred + lam @ cvals
    for i in range(B):
        # threshold above any attainable confidence forces one step
        final, depth = cascade_choice(int(choice[i]), conf[i], 2.0,
                                      order, 1, scores[i])
        if ladder_pos[choice[i]] == M - 1:
            assert depth == 0 and int(esc[i]) == int(choice[i])
        else:
            assert depth == 1 and int(esc[i]) == final


# ------------------------------------------------ engine-level parity

def _requests(n, seed=0):
    """Mixed-threshold workload: single-shot rows interleaved with
    shallow and deep escalation candidates."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(4, 64, size=(n, 32)).astype(np.int32)
    mb = mlm_batch(toks, rng, 0.2, 64)
    lam_mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]
    thr_mix = [0.0, 0.4, 0.8, 0.99]
    return [Request(uid=i, tokens=mb["tokens"][i], targets=mb["targets"][i],
                    mask=mb["mask"][i], lambdas=lam_mix[i % len(lam_mix)],
                    min_confidence=thr_mix[i % len(thr_mix)])
            for i in range(n)]


@pytest.fixture(scope="module")
def engines(tiny_library):
    """(staged, fused) engines over identical weights; the fused one is
    instrumented to prove the one-launch path actually ran."""
    rp, _ = init_router(jax.random.PRNGKey(9), RC, uncertainty=True)
    cons = [size_constraint(tiny_library), recency_constraint(tiny_library)]

    def mk(**kw):
        return TryageEngine(tiny_library, rp, RC, cons, max_batch=8,
                            use_kernel=True, cascade_max_depth=2, **kw)

    staged = mk()
    fused = mk(fused_cascade=True)
    fused._fused_calls = []
    orig = fused._score_cascade_batch
    fused._score_cascade_batch = (
        lambda reqs: (fused._fused_calls.append(len(reqs)), orig(reqs))[1])
    return staged, fused


def _by_uid(results):
    return sorted(results, key=lambda r: r.uid)


@pytest.mark.parametrize("discipline", ["run", "serve"])
def test_fused_engine_matches_staged(engines, discipline):
    staged, fused = engines
    reqs_a, reqs_b = _requests(37, seed=1), _requests(37, seed=1)
    if discipline == "run":
        for r in reqs_a:
            staged.submit(r)
        for r in reqs_b:
            fused.submit(r)
        res_s, res_f = _by_uid(staged.run()), _by_uid(fused.run())
    else:
        res_s = _by_uid(staged.serve(iter(reqs_a)))
        res_f = _by_uid(fused.serve(iter(reqs_b)))
    assert [r.uid for r in res_s] == [r.uid for r in res_f]
    assert [r.expert for r in res_s] == [r.expert for r in res_f]
    assert ([r.cascade_depth for r in res_s]
            == [r.cascade_depth for r in res_f])
    np.testing.assert_allclose([r.confidence for r in res_s],
                               [r.confidence for r in res_f], atol=1e-6)
    for a, b in zip(res_s, res_f):
        np.testing.assert_allclose(a.pred_losses, b.pred_losses, atol=1e-5)
    # the comparison is only meaningful if escalation traffic existed
    # and the fused engine actually took the one-launch path
    assert any(r.cascade_depth > 0 for r in res_s)
    assert fused._fused_calls


def test_fused_gate_degrades_to_staged_without_unc_head(tiny_library):
    """``fused_cascade=True`` with a router that has no uncertainty head
    is a no-op, not an error: the engine runs the staged path and
    matches a plain staged engine on the same weights."""
    rp, _ = init_router(jax.random.PRNGKey(9), RC)     # no "unc"
    cons = [size_constraint(tiny_library), recency_constraint(tiny_library)]

    def mk(**kw):
        return TryageEngine(tiny_library, rp, RC, cons, max_batch=8,
                            use_kernel=True, **kw)

    eng = mk(fused_cascade=True)
    ref = mk()
    assert not eng._use_fused_cascade(_requests(8))
    for r in _requests(8, seed=3):
        eng.submit(r)
    for r in _requests(8, seed=3):
        ref.submit(r)
    out, out_ref = _by_uid(eng.run()), _by_uid(ref.run())
    assert [r.expert for r in out] == [r.expert for r in out_ref]
    assert ([r.cascade_depth for r in out]
            == [r.cascade_depth for r in out_ref])
