"""jaxlint's own test suite: every rule fires on its known-bad fixture,
path scoping works, suppressions work, and — the gate that matters —
the repo's real code is clean.
"""

import json
import pathlib
import subprocess
import sys
import textwrap

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
TOOLS = ROOT / "tools"
FIXTURES = TOOLS / "jaxlint" / "fixtures"
if str(TOOLS) not in sys.path:
    sys.path.insert(0, str(TOOLS))

from jaxlint.core import RULES, analyze_paths  # noqa: E402


def scan(*paths, tests_dir=None):
    active, suppressed, errors, n = analyze_paths(
        [str(p) for p in paths],
        tests_dir=str(tests_dir or ROOT / "tests"))
    assert not errors, errors
    return active, suppressed


def codes(findings):
    return sorted(f.code for f in findings)


# ------------------------------------------------------------ fixtures

def test_every_rule_fires_on_the_fixture_suite():
    active, _ = scan(FIXTURES)
    assert {f.code for f in active} == set(RULES)


def test_jxl001_fixture():
    active, _ = scan(FIXTURES / "bad_jxl001.py")
    assert codes(active) == ["JXL001"] * 4
    # int(x.shape[0]) in `clean` is a host int already — never flagged
    assert all("shape" not in f.message for f in active)


def test_jxl002_fixture():
    active, _ = scan(FIXTURES / "bad_jxl002.py")
    assert codes(active) == ["JXL002"] * 2
    assert any("loop" in f.message for f in active)


def test_jxl003_fixture():
    active, _ = scan(FIXTURES / "bad_jxl003.py")
    assert codes(active) == ["JXL003"] * 3


def test_jxl004_fixture():
    active, _ = scan(FIXTURES / "bad_jxl004.py")
    assert codes(active) == ["JXL004"] * 3


def test_hot_path_fixture():
    active, _ = scan(FIXTURES / "src" / "repro" / "serving"
                     / "bad_hotpath.py")
    assert codes(active) == ["JXL001", "JXL001", "JXL002"]


def test_pallas_fixture():
    active, _ = scan(FIXTURES / "src" / "repro" / "kernels" / "badkern"
                     / "kernel.py")
    assert codes(active) == ["PLL001"] * 4 + ["PLL002"] * 2


# ------------------------------------------------------- path scoping

HOT_SNIPPET = textwrap.dedent("""\
    import jax

    score = jax.jit(lambda p, t: (p * t).sum())

    def step(p, t):
        return float(score(p, t))
""")


def test_hot_path_scalar_pull_is_scoped_to_serving(tmp_path):
    hot = tmp_path / "src" / "repro" / "serving" / "hot.py"
    hot.parent.mkdir(parents=True)
    hot.write_text(HOT_SNIPPET)
    cold = tmp_path / "offline" / "hot.py"
    cold.parent.mkdir(parents=True)
    cold.write_text(HOT_SNIPPET)
    active, _ = scan(hot)
    assert codes(active) == ["JXL001"]
    active, _ = scan(cold)
    assert active == []


def test_bare_prngkey_is_scoped_to_library_code(tmp_path):
    snippet = "import jax\nKEY = jax.random.PRNGKey(0)\n"
    lib = tmp_path / "src" / "pkg" / "mod.py"
    lib.parent.mkdir(parents=True)
    lib.write_text(snippet)
    entry = tmp_path / "scripts" / "run.py"
    entry.parent.mkdir(parents=True)
    entry.write_text(snippet)
    active, _ = scan(lib)
    assert codes(active) == ["JXL002"]
    active, _ = scan(entry)
    assert active == []


# ------------------------------------------------------- suppressions

def test_inline_suppression(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(textwrap.dedent("""\
        import jax
        import jax.numpy as jnp

        @jax.jit
        def g(x):
            s = float(jnp.sum(x))  # jaxlint: disable=JXL001
            return x * s
    """))
    active, suppressed = scan(f)
    assert active == []
    assert codes(suppressed) == ["JXL001"]


# ------------------------------------------------------ the real gate

def test_repo_is_clean():
    """The repo's own code passes jaxlint (the acceptance bar allows at
    most 3 justified inline suppressions)."""
    active, suppressed = scan(ROOT / "src", ROOT / "tests",
                              ROOT / "benchmarks")
    assert active == [], "\n".join(f.format() for f in active)
    assert len(suppressed) <= 3, "\n".join(f.format() for f in suppressed)


# --------------------------------------------------------------- CLI

def _run_cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "jaxlint", *args],
        cwd=ROOT, capture_output=True, text=True)


def test_cli_nonzero_on_fixtures_zero_on_repo(tmp_path):
    bad = _run_cli("tools/jaxlint/fixtures")
    assert bad.returncode == 1, bad.stdout + bad.stderr
    report = tmp_path / "report.json"
    good = _run_cli("src", "tests", "benchmarks", "--report", str(report))
    assert good.returncode == 0, good.stdout + good.stderr
    payload = json.loads(report.read_text())
    assert payload["findings"] == []
    assert payload["files_scanned"] > 0
    assert set(payload["rules"]) == set(RULES)


def test_cli_list_rules():
    out = _run_cli("--list-rules")
    assert out.returncode == 0
    for code in RULES:
        assert code in out.stdout


@pytest.mark.parametrize("code", sorted(RULES))
def test_rule_has_description_and_hint(code):
    desc, hint = RULES[code]
    assert desc and hint
