"""Speculative escalation in ``serve()`` (``speculate=True``).

The contracts under test:

* decisions are untouched — speculation changes *when* a request enters
  its lane, never *where* it ends up: expert, depth and confidence
  match the non-speculative engine request-for-request;
* exactly-once — every request yields exactly one Result, and the
  telemetry balances: ``spec_launched == spec_hits + spec_cancelled +
  spec_wasted`` after every serve;
* the cancel path (verdict lands while the entry is still queued) does
  no wasted compute; the wasted path (entry flushed before its verdict)
  reverts the discarded Result's per-request accounting;
* the soundness gates: a health tracker or an all-single-shot workload
  turns speculation off silently.

Deliberately hypothesis-free so the module runs without the optional
property-testing dep.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.objective import recency_constraint, size_constraint
from repro.core.router import RouterConfig, init_router
from repro.data.batching import mlm_batch
from repro.serving import Request, TryageEngine
from repro.serving.health import ExpertHealth
from repro.serving.scheduler import ExpertScheduler

RC = RouterConfig(n_models=3, vocab_size=64, num_layers=1, d_model=32,
                  num_heads=2, d_ff=64)


class Clock:
    def __init__(self, t=1.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture(scope="module")
def router_params():
    rp, _ = init_router(jax.random.PRNGKey(9), RC, uncertainty=True)
    return rp


def _requests(n, seed=0, thresholds=(0.0, 0.4, 0.99)):
    rng = np.random.default_rng(seed)
    toks = rng.integers(4, 64, size=(n, 32)).astype(np.int32)
    mb = mlm_batch(toks, rng, 0.2, 64)
    lam_mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]
    return [Request(uid=i, tokens=mb["tokens"][i], targets=mb["targets"][i],
                    mask=mb["mask"][i], lambdas=lam_mix[i % len(lam_mix)],
                    min_confidence=thresholds[i % len(thresholds)])
            for i in range(n)]


def _engine(library, params, clock, **kw):
    cons = [size_constraint(library), recency_constraint(library)]
    kw.setdefault("max_batch", 8)
    return TryageEngine(library, params, RC, cons, now_fn=clock, **kw)


def _check_exactly_once(eng, results, n):
    assert len(results) == n
    assert len({r.uid for r in results}) == n
    st = eng.stats
    assert st.spec_launched == (st.spec_hits + st.spec_cancelled
                                + st.spec_wasted), (
        "speculation accounting must balance")


def _by_uid(results):
    return sorted(results, key=lambda r: r.uid)


def test_decisions_match_nonspeculative(tiny_library, router_params):
    """Same workload through a speculative and a plain engine: the
    Results agree on every routing-visible field."""
    n = 40
    base = _engine(tiny_library, router_params, Clock())
    spec = _engine(tiny_library, router_params, Clock(), speculate=True)
    res_b = _by_uid(base.serve(iter(_requests(n))))
    res_s = _by_uid(spec.serve(iter(_requests(n))))
    _check_exactly_once(spec, res_s, n)
    assert spec.stats.spec_launched > 0
    assert [r.expert for r in res_b] == [r.expert for r in res_s]
    assert ([r.cascade_depth for r in res_b]
            == [r.cascade_depth for r in res_s])
    np.testing.assert_allclose([r.confidence for r in res_b],
                               [r.confidence for r in res_s], atol=1e-12)
    for a, b in zip(res_b, res_s):
        np.testing.assert_allclose(a.pred_losses, b.pred_losses)
    assert base.stats.escalations == spec.stats.escalations > 0
    assert base.stats.served == spec.stats.served == n


def test_cancel_path_no_wasted_compute(tiny_library, router_params):
    """Huge lane target + frozen clock: nothing flushes before the
    verdict lands, so every escalation cancels its provisional entry in
    place — zero wasted executions."""
    n = 24
    eng = _engine(tiny_library, router_params, Clock(), speculate=True,
                  lane_target=100, max_wait_s=100.0)
    results = _by_uid(eng.serve(iter(_requests(n, thresholds=(0.99,)))))
    _check_exactly_once(eng, results, n)
    st = eng.stats
    assert st.spec_launched == n                   # every row speculated
    assert st.spec_cancelled > 0
    assert st.spec_wasted == 0 and st.spec_wasted_tokens == 0
    assert st.escalations == st.spec_cancelled
    # every escalated Result came from a cancel+re-lane, confident rows
    # from an in-place confirm
    assert (sum(1 for r in results if r.cascade_depth > 0)
            == st.spec_cancelled)
    assert st.served == n


def test_wasted_path_reverts_accounting(tiny_library, router_params):
    """Lane target 1: every provisional entry flushes before its
    verdict, so each escalation discards an executed Result.  The
    replacement execution must leave per-request stats exactly-once."""
    n = 16
    eng = _engine(tiny_library, router_params, Clock(), speculate=True,
                  lane_target=1, max_wait_s=100.0)
    results = _by_uid(eng.serve(iter(_requests(n, thresholds=(0.99,)))))
    _check_exactly_once(eng, results, n)
    st = eng.stats
    assert st.spec_wasted > 0 and st.spec_cancelled == 0
    assert st.spec_wasted_tokens == st.spec_wasted * 32
    # discarded Results were reverted: per-request counters see each
    # request exactly once
    assert st.served == n
    assert sum(st.per_expert.values()) == n
    assert sum(st.cascade_depth_hist.values()) == n
    assert st.escalations == sum(1 for r in results if r.cascade_depth > 0)
    assert len(st.latencies) == n


def test_confirmed_speculation_flushes_in_lane(tiny_library, router_params):
    """All-confirm traffic (threshold low enough to hold): provisional
    entries are promoted in place and ride their original lane —
    spec_hits only, choices identical to the plain engine."""
    n = 24
    thr = (0.01,)
    base = _engine(tiny_library, router_params, Clock())
    spec = _engine(tiny_library, router_params, Clock(), speculate=True,
                   lane_target=100, max_wait_s=100.0)
    res_b = _by_uid(base.serve(iter(_requests(n, thresholds=thr))))
    res_s = _by_uid(spec.serve(iter(_requests(n, thresholds=thr))))
    _check_exactly_once(spec, res_s, n)
    st = spec.stats
    assert st.spec_launched == n == st.spec_hits
    assert st.spec_cancelled == st.spec_wasted == 0
    assert [r.expert for r in res_b] == [r.expert for r in res_s]
    np.testing.assert_allclose([r.confidence for r in res_b],
                               [r.confidence for r in res_s], atol=1e-12)


def test_speculation_off_is_byte_identical(tiny_library, router_params):
    """The gates that disable speculation (flag off; health tracker
    attached; no cascade traffic) reproduce the plain engine exactly —
    full Result dicts under a frozen clock."""

    def run(**kw):
        eng = _engine(tiny_library, router_params, Clock(), **kw)
        res = _by_uid(eng.serve(iter(_requests(24, thresholds=(0.0,)))))
        return eng, res

    def dicts(results):
        out = []
        for r in results:
            d = dataclasses.asdict(r)
            d["pred_losses"] = d["pred_losses"].tobytes()
            d["predictions"] = d["predictions"].tobytes()
            out.append(d)
        return out

    _, plain = run()
    for kw in ({"speculate": True},                       # no cascade rows
               {"speculate": False}):                     # flag off
        eng, res = run(**kw)
        assert eng.stats.spec_launched == 0
        assert dicts(res) == dicts(plain)
    # health tracker: speculation is refused, serve still works
    eng, res = run(speculate=True,
                   health=ExpertHealth(len(tiny_library)))
    assert eng.stats.spec_launched == 0
    assert len(res) == 24


def test_run_discipline_ignores_speculate(tiny_library, router_params):
    """``run()`` (FIFO drain) has no lanes to speculate into: the flag
    must be inert there."""
    eng = _engine(tiny_library, router_params, Clock(), speculate=True)
    for r in _requests(16):
        eng.submit(r)
    out = eng.run()
    assert len(out) == 16 and eng.stats.spec_launched == 0


# --------------------------------------------- scheduler cancel surface

def _req(uid, arrival):
    return Request(uid=uid, tokens=np.ones(8, np.int32), arrival=arrival)


def test_scheduler_remove_entry_recomputes_oldest():
    sched = ExpertScheduler(2, target=8, max_wait_s=1.0)
    pred = np.zeros(2, np.float32)
    sched.push(0, _req(1, arrival=1.0), pred, spec=True)
    sched.push(0, _req(2, arrival=2.0), pred, spec=True)
    sched.push(0, _req(3, arrival=3.0), pred)
    lane = sched.lanes[0]
    assert lane.oldest_wait(5.0) == 4.0
    en = sched.remove_entry(0, 1)                 # cancel the oldest
    assert en is not None and en.req.uid == 1 and en.spec
    assert lane.oldest_wait(5.0) == 3.0           # deadline clock moved
    assert sched.remove_entry(0, 99) is None      # already gone: no-op
    assert sched.find_entry(0, 2) is not None
    assert sched.find_entry(0, 2).spec
    en2 = sched.remove_entry(0, 2)
    assert en2.req.uid == 2
    assert lane.oldest_wait(5.0) == 2.0
    assert sched.pending == 1
    assert sched.remove_entry(0, 3).req.uid == 3
    assert lane.oldest_wait(5.0) == 0.0 and sched.pending == 0


def test_scheduler_find_entry_searches_regular_lane_only():
    """Speculative entries always carry depth 0, so the cancel surface
    only looks at regular lanes; escalation-lane traffic is invisible
    to it."""
    sched = ExpertScheduler(2, target=8, max_wait_s=1.0)
    pred = np.zeros(2, np.float32)
    sched.push(1, _req(7, arrival=1.0), pred, depth=1)    # esc lane
    assert sched.find_entry(1, 7) is None
    assert sched.remove_entry(1, 7) is None
    assert sched.pending == 1                     # esc entry untouched
