import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm
from repro.models.common import ModelConfig, SSMConfig


def _cfg(kind="mamba", d=32, heads=2):
    return ModelConfig(
        name="t", family="ssm", num_layers=1, d_model=d, num_heads=heads,
        num_kv_heads=heads, d_ff=0, vocab_size=64,
        ssm=SSMConfig(kind=kind, d_state=8, d_conv=4, expand=2,
                      num_heads=heads),
        layer_pattern=(kind,), moe_pattern=(False,), dtype="float32")


@pytest.mark.parametrize("cell", ["mamba", "mlstm", "slstm"])
def test_full_matches_stepwise(key, cell):
    """Parallel/chunked full-sequence path == sequential decode steps."""
    cfg = _cfg(cell)
    init = getattr(ssm, f"init_{cell}")
    full = getattr(ssm, f"{cell}_full")
    step = getattr(ssm, f"{cell}_step")
    p, _ = init(key, cfg, jnp.float32)
    T = 16
    x = jax.random.normal(key, (2, T, cfg.d_model)) * 0.5
    y_full, st_full = full(p, x, cfg)

    if cell == "mamba":
        st = ssm.init_mamba_state(2, cfg, jnp.float32)
    elif cell == "mlstm":
        st = ssm.init_mlstm_state(2, cfg)
    else:
        st = ssm.init_slstm_state(2, cfg)
    ys = []
    for t in range(T):
        y1, st = step(p, x[:, t:t + 1], st, cfg)
        ys.append(y1)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_seq),
                               atol=2e-4, rtol=1e-3)
    # final states agree too
    for a, b in zip(jax.tree.leaves(st_full), jax.tree.leaves(st)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("cell", ["mamba", "mlstm", "slstm"])
def test_state_carries_context(key, cell):
    """Changing early tokens must change late outputs (recurrence works)."""
    cfg = _cfg(cell)
    init = getattr(ssm, f"init_{cell}")
    full = getattr(ssm, f"{cell}_full")
    p, _ = init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 32, cfg.d_model))
    y1, _ = full(p, x, cfg)
    y2, _ = full(p, x.at[:, 0].mul(5.0), cfg)
    assert not np.allclose(np.asarray(y1[:, -1]), np.asarray(y2[:, -1]),
                           atol=1e-6)


def test_mamba_chunk_invariance(key):
    cfg = _cfg("mamba")
    p, _ = ssm.init_mamba(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 32, cfg.d_model)) * 0.5
    y1, _ = ssm.mamba_full(p, x, cfg, chunk=8)
    y2, _ = ssm.mamba_full(p, x, cfg, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)


def test_mlstm_grad_finite(key):
    cfg = _cfg("mlstm")
    p, _ = ssm.init_mlstm(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 16, cfg.d_model))

    def loss(pp):
        y, _ = ssm.mlstm_full(pp, x, cfg)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(p)
    assert all(jnp.isfinite(v).all() for v in jax.tree.leaves(g))
