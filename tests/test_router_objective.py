"""Routing-objective invariants — including hypothesis property tests on
the system's core math (eq. 1/4).  Deterministic tests run everywhere;
only the property-based tests skip when hypothesis is absent."""

import numpy as np

from hyputil import given, settings, st

from repro.core.library import ExpertSpec, ModelLibrary, _enc
from repro.core.objective import (Constraint, route, routing_scores,
                                  size_constraint)


def _library(sizes=(100, 200, 400)):
    lib = ModelLibrary([
        ExpertSpec(f"e{i}", _enc(f"e{i}", 2, 64, 2, 128, 64), {}, 0.5)
        for i in range(len(sizes))])
    for i, s in enumerate(sizes):
        lib.experts[i].n_params = s
    return lib


def test_lambda_zero_is_pure_argmin():
    pred = np.array([[0.3, 0.1, 0.5], [0.9, 0.8, 0.2]])
    c = size_constraint(_library())
    assert list(np.asarray(route(pred, [c], [0.0]))) == [1, 2]


def test_constraint_shifts_choice():
    lib = _library()
    pred = np.array([[0.30, 0.31, 0.29]])  # near-tie, biggest model best
    c = size_constraint(lib)
    assert int(route(pred)[0]) == 2
    assert int(route(pred, [c], [1.0])[0]) == 0  # strong size penalty


floats = st.floats(min_value=0.0, max_value=10.0, allow_nan=False,
                   width=32)


@given(pred=st.lists(st.lists(floats, min_size=3, max_size=3),
                     min_size=1, max_size=8),
       lam=st.floats(min_value=0.0, max_value=32.0, allow_nan=False))
@settings(max_examples=50, deadline=None)
def test_size_lambda_monotonicity(pred, lam):
    """Property (Pareto premise): increasing the size-penalty weight never
    increases the size of the selected model."""
    lib = _library()
    c = size_constraint(lib)
    pred = np.array(pred, np.float64)
    sizes = lib.sizes()
    pick_lo = np.asarray(route(pred, [c], [lam]))
    pick_hi = np.asarray(route(pred, [c], [lam * 2 + 1.0]))
    assert (sizes[pick_hi] <= sizes[pick_lo] + 1e-9).all()


@given(pred=st.lists(st.lists(floats, min_size=4, max_size=4),
                     min_size=1, max_size=6),
       lam=st.floats(min_value=0.0, max_value=8.0, allow_nan=False))
@settings(max_examples=40, deadline=None)
def test_routing_permutation_equivariance(pred, lam):
    """Permuting the model library permutes the routing decision."""
    pred = np.array(pred, np.float64)
    cvals = np.array([0.1, 0.5, 0.9, 0.3])
    c = Constraint("x", cvals)
    perm = np.array([2, 0, 3, 1])
    c_p = Constraint("x", cvals[perm])
    s1 = np.asarray(routing_scores(pred, [c], [lam]))
    s2 = np.asarray(routing_scores(pred[:, perm], [c_p], [lam]))
    np.testing.assert_allclose(s1[:, perm], s2, rtol=1e-9)


@given(pred=st.lists(st.lists(floats, min_size=3, max_size=3),
                     min_size=2, max_size=8))
@settings(max_examples=40, deadline=None)
def test_oracle_lower_bounds_any_policy(pred):
    """The oracle (argmin of true loss) achieves <= loss of any policy."""
    q = np.array(pred, np.float64)
    oracle = q.min(axis=1)
    for policy in range(3):
        assert (oracle <= q[:, policy] + 1e-12).all()


def test_objective_additivity():
    pred = np.random.default_rng(0).uniform(size=(5, 3))
    c1 = Constraint("a", np.array([0.1, 0.2, 0.3]))
    c2 = Constraint("b", np.array([0.5, 0.0, 0.5]))
    s = np.asarray(routing_scores(pred, [c1, c2], [2.0, 3.0]))
    expected = pred + 2.0 * c1.values + 3.0 * c2.values
    np.testing.assert_allclose(s, expected, rtol=1e-6)


def test_router_predicts_positive_losses(key):
    from repro.core.router import RouterConfig, init_router, predict_losses
    import jax
    rc = RouterConfig(n_models=5, vocab_size=64, num_layers=2, d_model=32,
                      num_heads=2, d_ff=64)
    p, _ = init_router(key, rc)
    toks = jax.random.randint(key, (3, 16), 1, 64)
    pred = predict_losses(p, rc, {"tokens": toks})
    assert pred.shape == (3, 5)
    assert bool((pred >= 0).all())


def test_router_kernel_path_matches_xla(key):
    import jax
    from repro.core.router import RouterConfig, init_router, predict_losses
    rc = RouterConfig(n_models=4, vocab_size=64, num_layers=2, d_model=32,
                      num_heads=2, d_ff=64)
    p, _ = init_router(key, rc)
    toks = jax.random.randint(key, (5, 16), 1, 64)
    a = predict_losses(p, rc, {"tokens": toks}, use_kernel=False)
    b = predict_losses(p, rc, {"tokens": toks}, use_kernel=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
