"""Synthetic-corpus invariants; the property-based MLM test skips when
hypothesis is absent (see ``hyputil``), the rest always run."""

import numpy as np

from hyputil import given, settings, st

from repro.data.batching import BatchIterator, mlm_batch
from repro.data.corpus import DOMAINS, DomainCorpus


def test_deterministic(corpus):
    r1 = np.random.default_rng(7)
    r2 = np.random.default_rng(7)
    a = corpus.sample_tokens("github", 4, 64, r1)
    b = corpus.sample_tokens("github", 4, 64, r2)
    np.testing.assert_array_equal(a, b)


def test_domains_have_distinct_statistics(corpus):
    """Private-vocabulary fingerprints must differ across domains (the
    Fig.-2 premise needs genuinely different distributions)."""
    rng = np.random.default_rng(0)
    hist = {}
    for d in DOMAINS:
        toks = corpus.sample_tokens(d, 16, 256, rng)
        h = np.bincount(toks.ravel(), minlength=corpus.vocab_size)
        hist[d] = h / h.sum()
    doms = list(DOMAINS)
    for i in range(len(doms)):
        for j in range(i + 1, len(doms)):
            tv = 0.5 * np.abs(hist[doms[i]] - hist[doms[j]]).sum()
            assert tv > 0.3, (doms[i], doms[j], tv)


def test_private_vocab_dominates_home_domain(corpus):
    rng = np.random.default_rng(1)
    toks = corpus.sample_tokens("uspto", 8, 256, rng)
    frac = np.isin(toks, corpus.private_vocab["uspto"]).mean()
    assert frac > 0.4


@given(mask_rate=st.floats(min_value=0.05, max_value=0.5), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_mlm_batch_properties(mask_rate, seed):
    corpus = DomainCorpus(vocab_size=256, seed=1)
    rng = np.random.default_rng(seed)
    toks = corpus.sample_tokens("books", 4, 128, rng)
    b = mlm_batch(toks, rng, mask_rate, 256)
    # unmasked positions pass through unchanged
    keep = b["mask"] == 0
    np.testing.assert_array_equal(b["tokens"][keep], b["targets"][keep])
    # targets are always the original tokens
    np.testing.assert_array_equal(b["targets"], toks)
    # realized mask rate in the right ballpark
    assert abs(b["mask"].mean() - mask_rate) < 0.15
    # no masking of position 0
    assert (b["mask"][:, 0] == 0).all()


def test_mixture_labels(corpus):
    rng = np.random.default_rng(3)
    toks, labels = corpus.sample_mixture({"github": 1.0}, 8, 64, rng)
    assert (labels == DOMAINS.index("github")).all()
    frac = np.isin(toks, corpus.private_vocab["github"]).mean()
    assert frac > 0.4


def test_batch_iterator(corpus):
    it = BatchIterator(corpus, {d: 1 / 8 for d in DOMAINS}, 8, 64, seed=0)
    b = next(it)
    assert b["tokens"].shape == (8, 64)
    assert set(b) == {"tokens", "targets", "mask", "domain"}
