"""Roofline-driven tile autotuner (``launch.autotune``) and the tile
table the kernels consult (``kernels.tiles``).

Modeled-only mode (``measure=False``) is deterministic, so the schema
and effective-tile honesty checks run it for real; wall-timing is
exercised on a single tiny candidate.  Table consultation is tested
against synthetic tables via the explicit ``path=`` argument so the
process-wide override/cache state is never touched.
"""

import json

import jax
import numpy as np
import pytest

from repro.kernels import tiles
from repro.kernels.router_score.kernel import launch_plan
from repro.launch import autotune as at
from repro.launch.roofline import PRESETS, Roofline, resolve_preset


# ------------------------------------------------------------ roofline

def test_presets_and_resolution():
    assert set(PRESETS) == {"tpu-v5e", "gpu", "cpu"}
    assert resolve_preset("gpu") is PRESETS["gpu"]
    # auto-detection lands on a real preset for the live backend
    assert resolve_preset("auto") in PRESETS.values()
    assert resolve_preset(None) in PRESETS.values()
    with pytest.raises(KeyError):
        resolve_preset("h100-from-the-future")


def test_roofline_uses_preset_ceilings():
    rl = Roofline(flops=1e12, hbm_bytes=1e9, collective_bytes=0.0,
                  hw=PRESETS["cpu"])
    assert rl.t_compute == pytest.approx(1.0)          # 1e12 / 1e12
    assert rl.t_memory == pytest.approx(1e9 / 100e9)
    assert rl.dominant == "compute" and rl.t_bound == pytest.approx(1.0)
    assert rl.as_dict()["hw"] == "cpu"
    # same totals under a faster preset: bound shrinks
    fast = Roofline(flops=1e12, hbm_bytes=1e9, collective_bytes=0.0,
                    hw=PRESETS["gpu"])
    assert fast.t_bound < rl.t_bound


# ------------------------------------------------------------ candidates

def test_router_candidates_effective_tiles_are_honest():
    """Every candidate's recorded effective tile equals the kernel's own
    launch-plan clamp, and clamped duplicates are deduped."""
    cands = at._router_candidates(96, np.random.default_rng(0))
    assert cands
    effs = [c.record["effective_block_b"] for c in cands]
    assert len(set(effs)) == len(effs)                  # deduped
    for c in cands:
        plan = launch_plan(96, c.params["block_b"])
        assert c.record["effective_block_b"] == plan["block_b"]
        assert c.record["grid"] == plan["grid"]
        assert c.record["effective_block_b"] <= 96


def test_measure_candidate_times_a_real_run():
    cands = at._router_candidates(32, np.random.default_rng(1))
    t = at.measure_candidate(cands[0], repeats=2)
    assert np.isfinite(t) and t > 0.0


# ------------------------------------------------------- tune + persist

@pytest.fixture(scope="module")
def modeled_table():
    """One deterministic modeled-only sweep of the router kernel."""
    return at.autotune(kernels=["router_score"], batches=(64,),
                       preset="cpu", measure=False)


def test_tune_kernel_modeled_schema(modeled_table):
    backend = jax.default_backend()
    assert modeled_table["version"] == 1
    entries = modeled_table[backend]["router_score"]
    assert set(entries) == {"64"}
    e = entries["64"]
    assert set(e) >= {"block_b", "effective_block_b", "grid",
                      "modeled_s", "measured_s"}
    assert e["modeled_s"] > 0.0
    assert e["measured_s"] is None                      # --no-measure
    assert e["effective_block_b"] == launch_plan(64, e["block_b"])["block_b"]
    # deterministic: a second identical sweep reproduces the table
    again = at.autotune(kernels=["router_score"], batches=(64,),
                        preset="cpu", measure=False)
    assert again == modeled_table


def test_write_and_merge_table(tmp_path, modeled_table):
    backend = jax.default_backend()
    path = str(tmp_path / "table.json")
    # pre-existing entries for a foreign backend and another kernel
    old = {"version": 1,
           "tpu": {"router_score": {"1000": {"block_b": 512}}},
           backend: {"flash_attention": {"8": {"block_q": 64}}}}
    at.write_table(old, path)
    merged = at.merge_table(modeled_table, path)
    at.write_table(merged, path)
    out = json.loads(open(path).read())
    assert out["tpu"]["router_score"]["1000"]["block_b"] == 512
    assert out[backend]["flash_attention"]["8"]["block_q"] == 64
    assert out[backend]["router_score"]["64"]["block_b"] \
        == modeled_table[backend]["router_score"]["64"]["block_b"]
    # merge over a missing/corrupt file degrades to the new table
    assert at.merge_table(modeled_table, str(tmp_path / "nope.json")) \
        == modeled_table
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert at.merge_table(modeled_table, str(bad)) == modeled_table


def test_kernels_consult_written_table(tmp_path, modeled_table):
    """End to end: a tuned table written to disk changes what the ops
    wrapper's tile consult returns."""
    backend = jax.default_backend()
    tuned = modeled_table[backend]["router_score"]["64"]["block_b"]
    path = str(tmp_path / "table.json")
    at.write_table(modeled_table, path)
    assert tiles.tile_for("router_score", 64, "block_b", 128,
                          path=path) == tuned
    # untabulated kernel falls back to the caller's default
    assert tiles.tile_for("router_cascade", 64, "block_b", 128,
                          path=path) == 128


# ------------------------------------------------------- tile_for rules

def _table(tmp_path, table):
    p = tmp_path / "t.json"
    p.write_text(json.dumps(table))
    return str(p)


def test_tile_for_batch_selection(tmp_path):
    path = _table(tmp_path, {
        "version": 1,
        "cpu": {"k": {"100": {"p": 32}, "400": {"p": 64}}}})
    # largest tabulated batch <= requested
    assert tiles.tile_for("k", 100, "p", 8, backend="cpu", path=path) == 32
    assert tiles.tile_for("k", 250, "p", 8, backend="cpu", path=path) == 32
    assert tiles.tile_for("k", 4000, "p", 8, backend="cpu", path=path) == 64
    # below the smallest entry: smallest entry is the best prior
    assert tiles.tile_for("k", 10, "p", 8, backend="cpu", path=path) == 32
    # unknown param / kernel / backend: default
    assert tiles.tile_for("k", 100, "q", 8, backend="cpu", path=path) == 8
    assert tiles.tile_for("nope", 100, "p", 8, backend="cpu",
                          path=path) == 8
    assert tiles.tile_for("k", 100, "p", 8, backend="tpu", path=path) == 8


def test_tile_for_never_raises(tmp_path):
    # missing file
    assert tiles.tile_for("k", 10, "p", 7,
                          path=str(tmp_path / "missing.json")) == 7
    # corrupt json
    bad = tmp_path / "bad.json"
    bad.write_text("[[[")
    assert tiles.tile_for("k", 10, "p", 7, path=str(bad)) == 7
    # wrong shapes inside an otherwise-valid file
    weird = _table(tmp_path, {"cpu": {"k": {"x": {"p": 1}, "8": 3}}})
    assert tiles.tile_for("k", 10, "p", 7, backend="cpu",
                          path=weird) == 7


def test_checked_in_table_is_valid():
    """The repo's own tile table parses and its router entries honour
    the effective-tile contract."""
    table = tiles.load_table(tiles.DEFAULT_PATH)
    assert table is not None and table.get("version") == 1
    for backend, kernels in table.items():
        if backend == "version":
            continue
        for b, e in kernels.get("router_score", {}).items():
            plan = launch_plan(int(b), e["block_b"])
            assert e["effective_block_b"] == plan["block_b"]
            assert e["grid"] == plan["grid"]
