"""End-to-end smoke test of the full experiment pipeline
(``repro.core.experiment.run_experiment``) at an ultra-reduced scale.

Exercises the whole paper loop — 11-expert library training, Q-table
construction, router training, every evaluation (selection accuracy,
allocation, silhouette, Pareto sweep) — structurally: shapes, ranges and
bookkeeping, not quality (2 training steps are noise).  Marked ``slow``
(~2-4 min on CPU); the CI coverage job runs it explicitly because it is
the only test that reaches the experiment driver itself.
"""

import numpy as np
import pytest

from repro.data.corpus import DOMAINS

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def reduced_results():
    from repro.core import experiment as ex
    xc = ex.ExperimentConfig(vocab=256, seq=32, expert_steps=2,
                             n_train_prompts=48, n_val_prompts=16,
                             n_test_per_domain=3, router_epochs=1)
    return ex.run_experiment(xc, verbose=False, save=False)


def test_experiment_reports_every_paper_quantity(reduced_results):
    res = reduced_results
    for key in ("router_eps", "selection_accuracy", "aggregate_accuracy",
                "per_domain", "allocation", "silhouette", "pareto",
                "library", "config"):
        assert key in res, key


def test_experiment_library_and_allocation_shapes(reduced_results):
    res = reduced_results
    assert len(res["library"]) == 11
    assert all(e["n_params"] > 0 for e in res["library"])
    alloc = np.array(res["allocation"])
    assert alloc.shape == (len(DOMAINS), 11)
    np.testing.assert_allclose(alloc.sum(axis=1), 1.0, atol=1e-6)


def test_experiment_metrics_in_range(reduced_results):
    res = reduced_results
    assert np.isfinite(res["router_eps"]) and res["router_eps"] >= 0
    for table in (res["selection_accuracy"], res["aggregate_accuracy"]):
        assert set(table) >= {"tryage", "oracle", "random", "largest"}
        assert all(0.0 <= v <= 1.0 for v in table.values())
    # the loss-oracle upper-bounds nothing in accuracy terms, but
    # selection accuracy of the oracle against itself is 1 by definition
    assert res["selection_accuracy"]["oracle"] == 1.0
    for d, row in res["per_domain"].items():
        assert d in DOMAINS
        assert all(0.0 <= v <= 1.0 for v in row.values())


def test_experiment_pareto_rows_monotone(reduced_results):
    rows = reduced_results["pareto"]["rows"]
    assert rows[0]["lam"] == 0.0
    sizes = [r["mean_size"] for r in rows]
    assert all(s2 <= s1 + 1e-6 for s1, s2 in zip(sizes, sizes[1:]))
