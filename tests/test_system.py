"""End-to-end behaviour tests for the Tryage system (integration scale:
small models, real training, real routing).

Marked ``slow`` as a module: the shared fixture trains a 3-expert
library plus router (~3 min on CPU).  The fast loop (`-m "not slow"`)
skips it; the CI coverage job runs it explicitly."""

import jax
import numpy as np
import pytest

from repro.core.library import ExpertSpec, ModelLibrary, _enc, _mix
from repro.core.qtable import build_q_table, mlm_accuracy
from repro.core.router import RouterConfig, init_router, predict_losses
from repro.core.training import train_library, train_router
from repro.core.experiment import _eval_batches
from repro.data.corpus import DOMAINS

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def system(corpus):
    """Two specialists + a generalist, lightly trained; router trained on
    their Q-table.  Slow-ish (~2-3 min) but exercises the whole paper."""
    lib = ModelLibrary([
        ExpertSpec("gen", _enc("gen", 2, 96, 2, 192, 512),
                   {d: 1 / 8 for d in DOMAINS}),
        ExpertSpec("code", _enc("code", 2, 96, 2, 192, 512),
                   _mix("github", "stackexchange", w=0.9)),
        ExpertSpec("patent", _enc("patent", 2, 96, 2, 192, 512),
                   _mix("uspto", "freelaw", w=0.9)),
    ])
    train_library(lib, corpus, steps=120, verbose=False)
    uniform = {d: 1 / 8 for d in DOMAINS}
    train_b = _eval_batches(corpus, uniform, 384, 128, 11)
    val_b = _eval_batches(corpus, uniform, 96, 128, 12)
    test_b = []
    for di, d in enumerate(DOMAINS):
        test_b += _eval_batches(corpus, {d: 1.0}, 24, 128, 13 + di)
    q_train = build_q_table(lib, train_b)
    q_val = build_q_table(lib, val_b)
    q_test = build_q_table(lib, test_b)
    rc = RouterConfig(n_models=3, vocab_size=512, num_layers=2, d_model=96)
    rp, _ = init_router(jax.random.PRNGKey(5), rc)
    cat = lambda bs: np.concatenate([b["tokens"] for b in bs])
    # at integration scale (384 prompts) the paper's lr=5e-5 undertrains;
    # use the same recipe the unit tests validated (lr 3e-4, 12 epochs)
    rp, log = train_router(
        rp, rc, {"tokens": cat(train_b), "loss": q_train["loss"]},
        {"tokens": cat(val_b), "loss": q_val["loss"]},
        epochs=12, lr=3e-4, verbose=False)
    test_tokens = cat(test_b)
    pred = np.asarray(jax.jit(
        lambda t: predict_losses(rp, rc, {"tokens": t}))(test_tokens))
    return dict(lib=lib, q_test=q_test, q_train=q_train, pred=pred,
                log=log, rc=rc, rp=rp, test_tokens=test_tokens,
                corpus=corpus)


def test_experts_are_differential(system):
    """Fig.-2 premise: the code specialist beats the patent specialist on
    github prompts and vice versa."""
    q, doms = system["q_test"], system["q_test"]["domain"]
    gh = doms == DOMAINS.index("github")
    us = doms == DOMAINS.index("uspto")
    acc = q["acc"]
    assert acc[gh, 1].mean() > acc[gh, 2].mean() + 0.02   # code > patent on gh
    assert acc[us, 2].mean() > acc[us, 1].mean() + 0.02   # patent > code on uspto


def test_router_training_converged(system):
    log = system["log"]
    assert log.val_loss[-1] <= log.val_loss[0]
    assert log.best_val < log.val_loss[0]


def test_router_beats_random_and_single_model(system):
    from repro.core import baselines as bl
    q, pred = system["q_test"], system["pred"]
    N = len(pred)
    tryage = pred.argmin(1)
    rand = bl.random_router(N, 3, 0)
    acc_t = mlm_accuracy(q, tryage)
    acc_r = mlm_accuracy(q, rand)
    assert acc_t > acc_r + 0.01
    sel_t = bl.selection_accuracy(tryage, q)
    sel_r = bl.selection_accuracy(rand, q)
    assert sel_t > sel_r


def test_tryage_near_oracle(system):
    from repro.core import baselines as bl
    q, pred = system["q_test"], system["pred"]
    acc_t = mlm_accuracy(q, pred.argmin(1))
    best_single = max(mlm_accuracy(q, np.full(len(pred), i))
                      for i in range(3))
    # aggregate >= best single model within tolerance; at this reduced
    # integration scale (3 lightly-trained experts, 24 prompts/domain) the
    # router sits within a few points of the best expert — the full-scale
    # claim (Tryage 0.323 vs oracle 0.346, above every expert) is
    # validated by repro.core.experiment / benchmarks fig3cd.
    assert acc_t >= best_single - 0.04
    # the LOSS-oracle is not accuracy-optimal (min-loss model can have
    # lower masked-token accuracy); the true upper bound is the
    # accuracy-oracle
    acc_upper = float(q["acc"].max(axis=1).mean())
    assert acc_t <= acc_upper + 1e-9


def test_pareto_tradeoff(system):
    from repro.core.objective import size_constraint
    from repro.core.pareto import pareto_sweep
    front = pareto_sweep(system["pred"], system["q_test"], system["lib"],
                         size_constraint(system["lib"]))
    rows = front["rows"]
    # mean selected size is non-increasing in lambda
    sizes = [r["mean_size"] for r in rows]
    assert all(s2 <= s1 + 1e-6 for s1, s2 in zip(sizes, sizes[1:]))
    # extreme lambda routes everything to the smallest model
    smallest = system["lib"].sizes().min()
    assert abs(rows[-1]["mean_size"] - smallest) < 1e-6


def test_e2e_cotraining_improves_routed_loss(system, corpus):
    from repro.core.e2e import cotrain
    st = cotrain(system["lib"], system["rp"], system["rc"], corpus,
                 steps=12, batch=16, seed=3)
    first = np.mean([h["routed_loss"] for h in st.history[:3]])
    last = np.mean([h["routed_loss"] for h in st.history[-3:]])
    assert last <= first + 0.05  # co-training must not regress


def test_qtable_shapes(system):
    q = system["q_test"]
    N = len(system["pred"])
    assert q["loss"].shape == (N, 3) and q["acc"].shape == (N, 3)
    assert np.isfinite(q["loss"]).all()
    assert ((q["acc"] >= 0) & (q["acc"] <= 1)).all()
