"""docs/METRICS.md <-> serving.metrics registry parity.

The markdown table between the ``metrics-table-start``/``-end`` markers
must list exactly the registry's series — same names, same order, same
types, labels, and sources.  A registry edit without the matching doc
edit (or vice versa) fails here.

Import-light on purpose (no JAX): this test also runs in the CI docs
job.
"""

import pathlib
import re

import pytest

from repro.serving.metrics import METRICS, metric_names

DOC = pathlib.Path(__file__).resolve().parent.parent / "docs" / "METRICS.md"


@pytest.fixture(scope="module")
def table_rows():
    text = DOC.read_text()
    m = re.search(r"<!-- metrics-table-start -->\n(.*?)"
                  r"<!-- metrics-table-end -->", text, re.DOTALL)
    assert m, "metrics table markers missing from docs/METRICS.md"
    lines = [ln for ln in m.group(1).strip().splitlines()
             if ln.startswith("|")]
    header, sep, *rows = lines
    assert [c.strip() for c in header.strip("|").split("|")] == \
        ["Name", "Type", "Labels", "Source", "Meaning"]
    assert set(sep) <= {"|", "-", " "}
    parsed = []
    for row in rows:
        cells = [c.strip() for c in row.strip("|").split("|")]
        assert len(cells) == 5, f"malformed row: {row}"
        parsed.append(cells)
    return parsed


def _unticked(cell):
    assert cell.startswith("`") and cell.endswith("`"), \
        f"expected backticked cell: {cell}"
    return cell[1:-1]


def test_table_names_match_registry_in_order(table_rows):
    assert [_unticked(r[0]) for r in table_rows] == metric_names()


def test_table_types_labels_sources_match_registry(table_rows):
    for row, spec in zip(table_rows, METRICS):
        name = _unticked(row[0])
        assert name == spec.name
        assert row[1] == spec.mtype, f"{name}: type drift"
        labels = "-" if not spec.labels else ", ".join(spec.labels)
        assert row[2] == labels, f"{name}: labels drift"
        assert _unticked(row[3]) == spec.source, f"{name}: source drift"


def test_table_meanings_match_registry_help(table_rows):
    for row, spec in zip(table_rows, METRICS):
        assert row[4] == spec.help, f"{spec.name}: help-string drift"


def test_doc_mentions_every_series_once(table_rows):
    names = [_unticked(r[0]) for r in table_rows]
    assert len(names) == len(set(names))
