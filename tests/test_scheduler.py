"""Continuous-batching scheduler edge cases: deadline flushes, priority
ordering, decision-cache parity, drain-on-shutdown, true latency —
plus hypothesis property tests (random op streams) for the scheduler's
exactly-once/ordering guarantees and the LRU cache vs a dict oracle.

Pure-scheduler tests need no models; engine-level tests run the tiny
3-expert library with an injectable fake clock so deadlines and
latencies are deterministic.
"""

import jax
import numpy as np
import pytest

from hyputil import given, settings, st

from repro.core.objective import recency_constraint, size_constraint
from repro.core.router import RouterConfig, init_router
from repro.data.batching import mlm_batch
from repro.serving import DecisionCache, ExpertScheduler, Request, TryageEngine
from repro.serving.scheduler import FLUSH_DEADLINE, FLUSH_DRAIN, FLUSH_TARGET


class Clock:
    """Manually-advanced monotonic clock."""

    def __init__(self, t=1.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _req(uid, priority=0, arrival=None, seed=None):
    rng = np.random.default_rng(uid if seed is None else seed)
    return Request(uid=uid, tokens=rng.integers(4, 64, 32).astype(np.int32),
                   priority=priority, arrival=arrival)


# ------------------------------------------------------ pure scheduler


def test_full_lane_flushes_exact_target_bucket():
    sched = ExpertScheduler(n_experts=2, target=4, max_wait_s=100.0)
    for i in range(5):
        sched.push(0, _req(i, arrival=1.0), np.zeros(2))
    flushes = list(sched.pop_ready(now=1.0))
    assert len(flushes) == 1
    mi, entries, reason = flushes[0]
    assert (mi, reason, len(entries)) == (0, FLUSH_TARGET, 4)
    assert sched.pending == 1                 # remainder stays in the lane


def test_priority_ordering_under_full_lane():
    """When a lane is over-full, the target flush takes the highest
    priorities first and keeps FIFO order among equals."""
    sched = ExpertScheduler(n_experts=1, target=4, max_wait_s=100.0)
    prios = [0, 5, 1, 0, 3, 0]
    for i, p in enumerate(prios):
        sched.push(0, _req(i, priority=p, arrival=1.0), np.zeros(2))
    ((_, entries, _),) = sched.pop_ready(now=1.0)
    assert [e.req.uid for e in entries] == [1, 4, 2, 0]   # 5, 3, 1, first 0
    assert sorted(e.req.uid for e in sched.lanes[0].entries) == [3, 5]


def test_deadline_flush_of_single_request_lane():
    """A lone request must not wait forever for a full bucket."""
    sched = ExpertScheduler(n_experts=2, target=8, max_wait_s=0.5)
    sched.push(1, _req(0, arrival=1.0), np.zeros(2))
    assert list(sched.pop_ready(now=1.2)) == []           # not due yet
    flushes = list(sched.pop_ready(now=1.6))
    assert len(flushes) == 1
    mi, entries, reason = flushes[0]
    assert (mi, reason, len(entries)) == (1, FLUSH_DEADLINE, 1)
    assert sched.pending == 0


def test_drain_flushes_everything():
    sched = ExpertScheduler(n_experts=3, target=4, max_wait_s=100.0)
    for i in range(7):
        sched.push(i % 3, _req(i, arrival=1.0), np.zeros(2))
    drained = [e.req.uid for _, ents, reason in sched.drain() for e in ents
               if reason == FLUSH_DRAIN]
    assert sorted(drained) == list(range(7))
    assert sched.pending == 0


# ------------------------- with engine (shared tiny_library fixture)


def _engine(library, clock, **kw):
    rc = RouterConfig(n_models=3, vocab_size=64, num_layers=1, d_model=32,
                      num_heads=2, d_ff=64)
    rp, _ = init_router(jax.random.PRNGKey(9), rc)
    cons = [size_constraint(library), recency_constraint(library)]
    kw.setdefault("max_batch", 8)
    return TryageEngine(library, rp, rc, cons, now_fn=clock, **kw)


def _requests(n, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(4, 64, size=(n, 32)).astype(np.int32)
    mb = mlm_batch(toks, rng, 0.2, 64)
    mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]
    return [Request(uid=i, tokens=mb["tokens"][i], targets=mb["targets"][i],
                    mask=mb["mask"][i], lambdas=mix[i % len(mix)])
            for i in range(n)]


def test_serve_deadline_flush_single_request(tiny_library):
    """One request trickles in, the lane never fills — the deadline tick
    must still flush it mid-stream, not at drain."""
    clock = Clock()
    eng = _engine(tiny_library, clock, lane_target=64, max_wait_s=1.0)

    def arrivals():
        yield _req(0, seed=3)       # admitted on the next idle tick
        yield None                  # routes the partial admission batch
        clock.advance(2.0)          # now past max_wait_s
        yield None                  # deadline tick
        pytest.fail("deadline flush must yield before the iterator ends")

    res = next(iter(eng.serve(arrivals())))
    assert res.uid == 0
    assert res.flush_reason == FLUSH_DEADLINE
    assert eng.stats.flushes[FLUSH_DEADLINE] == 1


def test_serve_drain_on_shutdown_leaves_nothing_behind(tiny_library):
    """Huge targets and deadlines: nothing flushes until the request
    iterator is exhausted, then every request drains exactly once."""
    clock = Clock()
    eng = _engine(tiny_library, clock, lane_target=1024, max_wait_s=1e9)
    results = list(eng.serve(iter(_requests(21, seed=1))))
    assert sorted(r.uid for r in results) == list(range(21))
    assert all(r.flush_reason == FLUSH_DRAIN for r in results)
    assert sum(eng.stats.flushes.values()) == eng.stats.flushes[FLUSH_DRAIN]


def test_serve_admits_presubmitted_queue(tiny_library):
    """Requests enqueued via submit() before serve() starts must flow
    through the streaming pipeline, not sit in the queue forever."""
    clock = Clock()
    eng = _engine(tiny_library, clock, lane_target=4, max_wait_s=1e9)
    for r in _requests(5, seed=6):
        eng.submit(r)
    results = list(eng.serve(iter([])))
    assert sorted(r.uid for r in results) == list(range(5))
    assert not eng.queue


def test_serve_partial_batch_coalesces_on_young_ticks(tiny_library):
    """Idle ticks must not degenerate scoring to batch-of-1: a partial
    admission batch is only scored once it has aged max_wait_s/2."""
    clock = Clock()
    eng = _engine(tiny_library, clock, max_batch=8, lane_target=8,
                  max_wait_s=1.0)
    reqs = _requests(4, seed=8)

    def arrivals():
        for r in reqs:
            yield r
            yield None              # young tick between arrivals: no admit
        clock.advance(1.0)
        yield None                  # aged tick: one batched router pass

    results = list(eng.serve(arrivals()))
    assert sorted(r.uid for r in results) == list(range(4))
    # all four requests were scored in a single batched router pass
    assert eng.stats.router_batches == 1
    assert eng.stats.flushes["deadline"] >= 1


def test_serve_matches_fifo_choices(tiny_library):
    """Same workload, same weights: the scheduler discipline must not
    change which expert any request is routed to."""
    clock = Clock()
    fifo = _engine(tiny_library, clock, decision_cache=False)
    stream = _engine(tiny_library, clock, decision_cache=False, lane_target=4,
                     max_wait_s=1e9)
    for r in _requests(21, seed=2):
        fifo.submit(r)
    by_uid = {r.uid: r.expert for r in fifo.run()}
    for r in stream.serve(iter(_requests(21, seed=2))):
        assert by_uid[r.uid] == r.expert


def test_cache_hit_identical_to_fresh_score(tiny_library):
    """A cache hit must return exactly the choice and predicted losses a
    fresh score produces, and must be flagged on the Result."""
    clock = Clock()
    eng = _engine(tiny_library, clock)
    reqs = _requests(6, seed=4)
    for r in reqs:
        eng.submit(r)
    first = {r.uid: r for r in eng.run()}
    assert eng.stats.cache_hits == 0 and eng.stats.cache_misses == 6
    # identical tokens + lambdas again under fresh uids
    again = _requests(6, seed=4)
    for r in again:
        eng.submit(r)
    second = {r.uid: r for r in eng.run()}
    assert eng.stats.cache_hits == 6
    for uid in first:
        assert second[uid].expert == first[uid].expert
        assert second[uid].cached and not first[uid].cached
        np.testing.assert_array_equal(second[uid].pred_losses,
                                      first[uid].pred_losses)


def test_cache_distinguishes_lambda_vectors():
    """Same tokens under a different lambda vector is a different key."""
    cache = DecisionCache(capacity=8)
    toks = np.arange(32, dtype=np.int32)
    k1 = DecisionCache.key(toks, {}, ["size"])
    k2 = DecisionCache.key(toks, {"size": 8.0}, ["size"])
    assert k1 != k2
    cache.put(k1, np.zeros(3), 0)
    assert cache.get(k2) is None and cache.get(k1) is not None


def test_cache_lru_eviction():
    cache = DecisionCache(capacity=2)
    keys = [DecisionCache.key(np.array([i], np.int32), {}, []) for i in range(3)]
    cache.put(keys[0], np.zeros(1), 0)
    cache.put(keys[1], np.zeros(1), 0)
    assert cache.get(keys[0]) is not None     # refresh 0 -> 1 becomes LRU
    cache.put(keys[2], np.zeros(1), 0)        # evicts 1
    assert cache.get(keys[1]) is None
    assert cache.get(keys[0]) is not None and cache.get(keys[2]) is not None


def test_latency_is_enqueue_to_flush(tiny_library):
    """Result.latency_s reports true enqueue->flush wall time, not the
    micro-batch time split evenly across the batch."""
    clock = Clock()
    eng = _engine(tiny_library, clock)
    for r in _requests(4, seed=5):
        eng.submit(r)                          # arrival stamped at t=1.0
    clock.advance(2.5)                         # queue wait before the drain
    results = eng.run()                        # fake clock: execution is 0s
    assert all(r.latency_s == pytest.approx(2.5) for r in results)
    p = eng.stats.latency_percentiles()
    assert p["p50_s"] == pytest.approx(2.5)
    assert p["p95_s"] == pytest.approx(2.5)


# --------------------------------------------- property tests (hypothesis)


# an op stream: ("push", lane, priority) interleaved with "flush" ticks
_ops = st.lists(
    st.one_of(st.tuples(st.just("push"), st.integers(0, 2),
                        st.integers(0, 3)),
              st.just("flush")),
    min_size=1, max_size=48)


@given(ops=_ops, target=st.integers(1, 6))
@settings(max_examples=60, deadline=None)
def test_flushes_never_reorder_same_priority(ops, target):
    """Across any interleaving of pushes and flush ticks, requests of
    equal priority leave their lane in admission order (seq strictly
    increasing per (lane, priority)), and nothing is lost or duplicated."""
    sched = ExpertScheduler(n_experts=3, target=target, max_wait_s=1e9)
    pushed, emitted = [], []
    uid = 0
    for op in ops:
        if op == "flush":
            for mi, entries, _ in sched.pop_ready(now=1.0):
                emitted.extend((mi, e) for e in entries)
        else:
            _, lane, prio = op
            sched.push(lane, _req(uid, priority=prio, arrival=1.0),
                       np.zeros(3))
            pushed.append(uid)
            uid += 1
    for mi, entries, _ in sched.drain():
        emitted.extend((mi, e) for e in entries)
    # exactly once
    assert sorted(e.req.uid for _, e in emitted) == sorted(pushed)
    assert sched.pending == 0
    # same-priority admission order preserved per lane
    seen: dict = {}
    for mi, e in emitted:
        key = (mi, e.req.priority)
        assert seen.get(key, -1) < e.seq, (key, e.seq)
        seen[key] = e.seq


@given(ops=_ops, target=st.integers(1, 4), esc=st.booleans())
@settings(max_examples=40, deadline=None)
def test_escalation_lanes_share_exactly_once_guarantee(ops, target, esc):
    """Pushing the same stream through escalation lanes (depth > 0) must
    preserve the exactly-once guarantee and keep tiers separate."""
    sched = ExpertScheduler(n_experts=3, target=target, max_wait_s=1e9)
    pushed, emitted = [], []
    uid = 0
    for op in ops:
        if op == "flush":
            emitted += [e for _, ents, _ in sched.pop_ready(now=1.0)
                        for e in ents]
        else:
            _, lane, prio = op
            depth = 1 if esc else 0
            sched.push(lane, _req(uid, priority=prio, arrival=1.0),
                       np.zeros(3), depth=depth)
            pushed.append(uid)
            uid += 1
    emitted += [e for _, ents, _ in sched.drain() for e in ents]
    assert sorted(e.req.uid for e in emitted) == sorted(pushed)
    assert all(e.depth == (1 if esc else 0) for e in emitted)
    if esc and pushed:
        assert sched.esc_peaks() and not sched.peaks()


class _LRUOracle:
    """Dict/list-based LRU reference: MRU at the end of a plain list."""

    def __init__(self, capacity):
        self.capacity = capacity
        self.items = []                      # list of (key, value)

    def get(self, key):
        for i, (k, v) in enumerate(self.items):
            if k == key:
                self.items.append(self.items.pop(i))
                return v
        return None

    def put(self, key, value):
        for i, (k, _) in enumerate(self.items):
            if k == key:
                self.items.pop(i)
                break
        self.items.append((key, value))
        while len(self.items) > self.capacity:
            self.items.pop(0)


_cache_ops = st.lists(
    st.tuples(st.sampled_from(["get", "put"]), st.integers(0, 5)),
    min_size=1, max_size=60)


@given(ops=_cache_ops, capacity=st.integers(1, 4))
@settings(max_examples=60, deadline=None)
def test_lru_cache_matches_dict_oracle(ops, capacity):
    """DecisionCache hit/miss and eviction behaviour must match a naive
    list-based LRU oracle under arbitrary get/put interleavings."""
    cache = DecisionCache(capacity=capacity)
    oracle = _LRUOracle(capacity)
    for i, (op, k) in enumerate(ops):
        key = ("k", k)
        if op == "get":
            hit = cache.get(key)
            expect = oracle.get(key)
            if expect is None:
                assert hit is None
            else:
                assert hit is not None and hit[1] == expect
        else:
            cache.put(key, np.full(1, i, np.float32), i)
            oracle.put(key, i)
        assert len(cache) == len(oracle.items) <= capacity
    # final state: same keys survive, same recency order under eviction
    for k, v in oracle.items:
        hit = cache.get(k)
        assert hit is not None and hit[1] == v


@given(uids=st.lists(st.integers(0, 7), min_size=1, max_size=24),
       thresholds=st.lists(st.sampled_from([0.0, 0.5, 0.9]),
                           min_size=1, max_size=3))
@settings(max_examples=15, deadline=None)
def test_serve_emits_every_admitted_request_once(tiny_library, uids,
                                                 thresholds):
    """Engine-level exactly-once: random arrival streams (with idle
    ticks, repeated prompts, mixed flags and cascade thresholds) must
    come back out of serve() exactly once each."""
    clock = Clock()
    eng = _engine(tiny_library, clock, lane_target=4, max_wait_s=1.0)
    mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]

    def stream():
        for i, u in enumerate(uids):
            rng = np.random.default_rng(u)      # repeated prompts cache-hit
            yield Request(
                uid=i, tokens=rng.integers(4, 64, 32).astype(np.int32),
                lambdas=mix[u % len(mix)],
                min_confidence=thresholds[i % len(thresholds)])
            if u % 3 == 0:
                clock.advance(0.7)              # age toward deadline
                yield None
    results = list(eng.serve(stream()))
    assert sorted(r.uid for r in results) == list(range(len(uids)))


# ------------------------------------- latent-bug regressions (PR 8)


def test_cache_hit_pred_row_is_readonly():
    """A cached pred row is shared by reference across hits; mutating a
    hit must raise instead of silently corrupting every later hit."""
    cache = DecisionCache(capacity=4)
    k = DecisionCache.key(np.arange(8, dtype=np.int32), {}, ["size"])
    cache.put(k, np.array([0.5, 1.5, 2.5], np.float32), choice=0)
    pred, choice, _, _ = cache.get(k)
    with pytest.raises(ValueError):
        pred[choice] = -1.0                   # the old silent corruption
    again, _, _, _ = cache.get(k)
    np.testing.assert_array_equal(again, [0.5, 1.5, 2.5])


def test_drain_labels_full_buckets_as_target():
    """drain() emits FLUSH_TARGET for every full bucket and reserves
    FLUSH_DRAIN for the ragged tail, so flush telemetry distinguishes
    healthy batching from shutdown stragglers."""
    sched = ExpertScheduler(n_experts=1, target=4, max_wait_s=100.0)
    for i in range(9):
        sched.push(0, _req(i, arrival=1.0), np.zeros(2))
    # pop_ready would already take two full buckets; go straight to drain
    flushes = list(sched.drain())
    assert [(len(e), reason) for _, e, reason in flushes] == [
        (4, FLUSH_TARGET), (4, FLUSH_TARGET), (1, FLUSH_DRAIN)]
    assert sched.pending == 0


def test_drain_exact_target_lane_is_all_target():
    """A lane holding exactly one full bucket drains with no
    FLUSH_DRAIN tail at all."""
    sched = ExpertScheduler(n_experts=2, target=3, max_wait_s=100.0)
    for i in range(3):
        sched.push(1, _req(i, arrival=1.0), np.zeros(2))
    flushes = list(sched.drain())
    assert [(mi, len(e), r) for mi, e, r in flushes] == [(1, 3, FLUSH_TARGET)]


@given(ops=st.lists(
    st.one_of(st.tuples(st.just("push"),
                        st.floats(0.0, 100.0, allow_nan=False)),
              st.tuples(st.just("take"), st.integers(1, 4))),
    min_size=1, max_size=40))
@settings(max_examples=60, deadline=None)
def test_oldest_wait_matches_naive_rescan(ops):
    """The incremental oldest-arrival tracker must agree with a full
    re-scan of the lane after every push/take (both compute the same
    min over the same floats, so equality is exact)."""
    from repro.serving.scheduler import Lane, LaneEntry

    lane = Lane(0)
    uid = 0
    for op in ops:
        if op[0] == "push":
            lane.push(LaneEntry(req=_req(uid, arrival=op[1]),
                                pred=np.zeros(2), seq=uid))
            uid += 1
        else:
            lane.take(op[1])
        now = 200.0
        arrivals = [e.req.arrival for e in lane.entries
                    if e.req.arrival is not None]
        naive = (now - min(arrivals)) if arrivals else 0.0
        assert lane.oldest_wait(now) == naive
