"""Continuous-batching scheduler edge cases: deadline flushes, priority
ordering, decision-cache parity, drain-on-shutdown, true latency.

Pure-scheduler tests need no models; engine-level tests run the tiny
3-expert library with an injectable fake clock so deadlines and
latencies are deterministic.
"""

import jax
import numpy as np
import pytest

from repro.core.objective import recency_constraint, size_constraint
from repro.core.router import RouterConfig, init_router
from repro.data.batching import mlm_batch
from repro.serving import DecisionCache, ExpertScheduler, Request, TryageEngine
from repro.serving.scheduler import FLUSH_DEADLINE, FLUSH_DRAIN, FLUSH_TARGET


class Clock:
    """Manually-advanced monotonic clock."""

    def __init__(self, t=1.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _req(uid, priority=0, arrival=None, seed=None):
    rng = np.random.default_rng(uid if seed is None else seed)
    return Request(uid=uid, tokens=rng.integers(4, 64, 32).astype(np.int32),
                   priority=priority, arrival=arrival)


# ------------------------------------------------------ pure scheduler


def test_full_lane_flushes_exact_target_bucket():
    sched = ExpertScheduler(n_experts=2, target=4, max_wait_s=100.0)
    for i in range(5):
        sched.push(0, _req(i, arrival=1.0), np.zeros(2))
    flushes = list(sched.pop_ready(now=1.0))
    assert len(flushes) == 1
    mi, entries, reason = flushes[0]
    assert (mi, reason, len(entries)) == (0, FLUSH_TARGET, 4)
    assert sched.pending == 1                 # remainder stays in the lane


def test_priority_ordering_under_full_lane():
    """When a lane is over-full, the target flush takes the highest
    priorities first and keeps FIFO order among equals."""
    sched = ExpertScheduler(n_experts=1, target=4, max_wait_s=100.0)
    prios = [0, 5, 1, 0, 3, 0]
    for i, p in enumerate(prios):
        sched.push(0, _req(i, priority=p, arrival=1.0), np.zeros(2))
    ((_, entries, _),) = sched.pop_ready(now=1.0)
    assert [e.req.uid for e in entries] == [1, 4, 2, 0]   # 5, 3, 1, first 0
    assert sorted(e.req.uid for e in sched.lanes[0].entries) == [3, 5]


def test_deadline_flush_of_single_request_lane():
    """A lone request must not wait forever for a full bucket."""
    sched = ExpertScheduler(n_experts=2, target=8, max_wait_s=0.5)
    sched.push(1, _req(0, arrival=1.0), np.zeros(2))
    assert list(sched.pop_ready(now=1.2)) == []           # not due yet
    flushes = list(sched.pop_ready(now=1.6))
    assert len(flushes) == 1
    mi, entries, reason = flushes[0]
    assert (mi, reason, len(entries)) == (1, FLUSH_DEADLINE, 1)
    assert sched.pending == 0


def test_drain_flushes_everything():
    sched = ExpertScheduler(n_experts=3, target=4, max_wait_s=100.0)
    for i in range(7):
        sched.push(i % 3, _req(i, arrival=1.0), np.zeros(2))
    drained = [e.req.uid for _, ents, reason in sched.drain() for e in ents
               if reason == FLUSH_DRAIN]
    assert sorted(drained) == list(range(7))
    assert sched.pending == 0


# ------------------------- with engine (shared tiny_library fixture)


def _engine(library, clock, **kw):
    rc = RouterConfig(n_models=3, vocab_size=64, num_layers=1, d_model=32,
                      num_heads=2, d_ff=64)
    rp, _ = init_router(jax.random.PRNGKey(9), rc)
    cons = [size_constraint(library), recency_constraint(library)]
    kw.setdefault("max_batch", 8)
    return TryageEngine(library, rp, rc, cons, now_fn=clock, **kw)


def _requests(n, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(4, 64, size=(n, 32)).astype(np.int32)
    mb = mlm_batch(toks, rng, 0.2, 64)
    mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]
    return [Request(uid=i, tokens=mb["tokens"][i], targets=mb["targets"][i],
                    mask=mb["mask"][i], lambdas=mix[i % len(mix)])
            for i in range(n)]


def test_serve_deadline_flush_single_request(tiny_library):
    """One request trickles in, the lane never fills — the deadline tick
    must still flush it mid-stream, not at drain."""
    clock = Clock()
    eng = _engine(tiny_library, clock, lane_target=64, max_wait_s=1.0)

    def arrivals():
        yield _req(0, seed=3)       # admitted on the next idle tick
        yield None                  # routes the partial admission batch
        clock.advance(2.0)          # now past max_wait_s
        yield None                  # deadline tick
        pytest.fail("deadline flush must yield before the iterator ends")

    res = next(iter(eng.serve(arrivals())))
    assert res.uid == 0
    assert res.flush_reason == FLUSH_DEADLINE
    assert eng.stats.flushes[FLUSH_DEADLINE] == 1


def test_serve_drain_on_shutdown_leaves_nothing_behind(tiny_library):
    """Huge targets and deadlines: nothing flushes until the request
    iterator is exhausted, then every request drains exactly once."""
    clock = Clock()
    eng = _engine(tiny_library, clock, lane_target=1024, max_wait_s=1e9)
    results = list(eng.serve(iter(_requests(21, seed=1))))
    assert sorted(r.uid for r in results) == list(range(21))
    assert all(r.flush_reason == FLUSH_DRAIN for r in results)
    assert sum(eng.stats.flushes.values()) == eng.stats.flushes[FLUSH_DRAIN]


def test_serve_admits_presubmitted_queue(tiny_library):
    """Requests enqueued via submit() before serve() starts must flow
    through the streaming pipeline, not sit in the queue forever."""
    clock = Clock()
    eng = _engine(tiny_library, clock, lane_target=4, max_wait_s=1e9)
    for r in _requests(5, seed=6):
        eng.submit(r)
    results = list(eng.serve(iter([])))
    assert sorted(r.uid for r in results) == list(range(5))
    assert not eng.queue


def test_serve_partial_batch_coalesces_on_young_ticks(tiny_library):
    """Idle ticks must not degenerate scoring to batch-of-1: a partial
    admission batch is only scored once it has aged max_wait_s/2."""
    clock = Clock()
    eng = _engine(tiny_library, clock, max_batch=8, lane_target=8,
                  max_wait_s=1.0)
    reqs = _requests(4, seed=8)

    def arrivals():
        for r in reqs:
            yield r
            yield None              # young tick between arrivals: no admit
        clock.advance(1.0)
        yield None                  # aged tick: one batched router pass

    results = list(eng.serve(arrivals()))
    assert sorted(r.uid for r in results) == list(range(4))
    # all four requests were scored in a single batched router pass
    assert eng.stats.router_batches == 1
    assert eng.stats.flushes["deadline"] >= 1


def test_serve_matches_fifo_choices(tiny_library):
    """Same workload, same weights: the scheduler discipline must not
    change which expert any request is routed to."""
    clock = Clock()
    fifo = _engine(tiny_library, clock, decision_cache=False)
    stream = _engine(tiny_library, clock, decision_cache=False, lane_target=4,
                     max_wait_s=1e9)
    for r in _requests(21, seed=2):
        fifo.submit(r)
    by_uid = {r.uid: r.expert for r in fifo.run()}
    for r in stream.serve(iter(_requests(21, seed=2))):
        assert by_uid[r.uid] == r.expert


def test_cache_hit_identical_to_fresh_score(tiny_library):
    """A cache hit must return exactly the choice and predicted losses a
    fresh score produces, and must be flagged on the Result."""
    clock = Clock()
    eng = _engine(tiny_library, clock)
    reqs = _requests(6, seed=4)
    for r in reqs:
        eng.submit(r)
    first = {r.uid: r for r in eng.run()}
    assert eng.stats.cache_hits == 0 and eng.stats.cache_misses == 6
    # identical tokens + lambdas again under fresh uids
    again = _requests(6, seed=4)
    for r in again:
        eng.submit(r)
    second = {r.uid: r for r in eng.run()}
    assert eng.stats.cache_hits == 6
    for uid in first:
        assert second[uid].expert == first[uid].expert
        assert second[uid].cached and not first[uid].cached
        np.testing.assert_array_equal(second[uid].pred_losses,
                                      first[uid].pred_losses)


def test_cache_distinguishes_lambda_vectors():
    """Same tokens under a different lambda vector is a different key."""
    cache = DecisionCache(capacity=8)
    toks = np.arange(32, dtype=np.int32)
    k1 = DecisionCache.key(toks, {}, ["size"])
    k2 = DecisionCache.key(toks, {"size": 8.0}, ["size"])
    assert k1 != k2
    cache.put(k1, np.zeros(3), 0)
    assert cache.get(k2) is None and cache.get(k1) is not None


def test_cache_lru_eviction():
    cache = DecisionCache(capacity=2)
    keys = [DecisionCache.key(np.array([i], np.int32), {}, []) for i in range(3)]
    cache.put(keys[0], np.zeros(1), 0)
    cache.put(keys[1], np.zeros(1), 0)
    assert cache.get(keys[0]) is not None     # refresh 0 -> 1 becomes LRU
    cache.put(keys[2], np.zeros(1), 0)        # evicts 1
    assert cache.get(keys[1]) is None
    assert cache.get(keys[0]) is not None and cache.get(keys[2]) is not None


def test_latency_is_enqueue_to_flush(tiny_library):
    """Result.latency_s reports true enqueue->flush wall time, not the
    micro-batch time split evenly across the batch."""
    clock = Clock()
    eng = _engine(tiny_library, clock)
    for r in _requests(4, seed=5):
        eng.submit(r)                          # arrival stamped at t=1.0
    clock.advance(2.5)                         # queue wait before the drain
    results = eng.run()                        # fake clock: execution is 0s
    assert all(r.latency_s == pytest.approx(2.5) for r in results)
    p = eng.stats.latency_percentiles()
    assert p["p50_s"] == pytest.approx(2.5)
    assert p["p95_s"] == pytest.approx(2.5)
