"""Mesh-sharded serving: placement planning, per-device stream
bookkeeping, mesh-construction validation, and the multi-device parity
suite.

The load-bearing contract is feature-off parity: a ``(1, 1)`` mesh
engine must be **bit-for-bit** the meshless engine — identical Results
and identical ``EngineStats`` — including cascade escalations and
health-fallback reroute traffic, under both the host and the fused
Pallas scoring paths.  Mesh telemetry (placement map, stream clocks)
lives outside ``EngineStats`` precisely so this holds by construction.

Multi-device tests need the CI mesh leg's 8 virtual CPU devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``, set before jax
imports) and skip elsewhere; they pin the sharded engine's routing
choices to the single-device engine's exactly and its measured
per-request NLLs to within float tolerance.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.router import RouterConfig, init_router
from repro.data.batching import mlm_batch
from repro.launch.mesh import make_host_mesh
from repro.serving import ExpertHealth, ExpertScheduler, Request, TryageEngine
from repro.serving.placement import PlacementMap, StreamClock, plan_placement

RC = RouterConfig(n_models=3, vocab_size=64, num_layers=1, d_model=32,
                  num_heads=2, d_ff=64)

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


class Clock:
    def __init__(self, t=1.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture(scope="module")
def router_params():
    rp, _ = init_router(jax.random.PRNGKey(9), RC)
    return rp


def _requests(n, seed=0, min_confidence=0.0, n_unique=None):
    n_unique = n if n_unique is None else n_unique
    rng = np.random.default_rng(seed)
    toks = rng.integers(4, 64, size=(n_unique, 32)).astype(np.int32)
    mb = mlm_batch(toks, rng, 0.2, 64)
    mix = [{}, {"size": 1.0}, {"size": 8.0}, {"recency": 2.0}]
    return [Request(uid=i, tokens=mb["tokens"][i % n_unique],
                    targets=mb["targets"][i % n_unique],
                    mask=mb["mask"][i % n_unique],
                    lambdas=mix[i % len(mix)],
                    min_confidence=min_confidence)
            for i in range(n)]


def _engine(library, params, clock, **kw):
    from repro.core.objective import recency_constraint, size_constraint
    cons = [size_constraint(library), recency_constraint(library)]
    kw.setdefault("max_batch", 32)
    return TryageEngine(library, params, RC, cons, now_fn=clock, **kw)


def _result_key(r):
    d = dataclasses.asdict(r)
    d["pred_losses"] = d["pred_losses"].tobytes()
    d["predictions"] = d["predictions"].tobytes()
    return d


def _hot_expert(library, params, reqs):
    """Post-cascade routing argmax-by-traffic, computed on a throwaway
    scout engine so the engines under test keep pristine stats."""
    scout = _engine(library, params, Clock())
    pred, choice = scout._score_batch(reqs)
    choice, _, _ = scout._cascade(reqs, pred, choice)
    return int(np.bincount(np.asarray(choice), minlength=3).argmax())


# ---------------------------------------------------- placement planning


def test_plan_placement_is_lpt_balanced_and_deterministic():
    sizes = [8.0, 7.0, 3.0, 2.0, 1.0, 1.0]
    pm = plan_placement(sizes, n_slices=2)
    # LPT walk: 8->s0, 7->s1, 3->s1, 2->s0, 1->s0 (tie, low index), 1->s1
    assert [pm.home(i) for i in range(6)] == [0, 1, 1, 0, 0, 1]
    per_slice = [sum(sizes[i] for i in range(6) if pm.home(i) == k)
                 for k in range(2)]
    assert per_slice == [11.0, 11.0]
    assert pm == plan_placement(sizes, n_slices=2)       # deterministic
    assert not any(pm.replicated(i) for i in range(6))


def test_plan_placement_traffic_weights_override_size():
    """A small expert carrying all the traffic becomes the heaviest
    load and claims its own slice."""
    sizes = [100.0, 1.0]
    uniform = plan_placement(sizes, n_slices=2)
    skewed = plan_placement(sizes, n_slices=2, traffic=[0.001, 0.999])
    assert uniform.home(0) == 0                          # size order
    assert skewed.home(1) == 0                           # load order
    assert skewed.home(0) == 1


def test_plan_placement_replicates_hot_experts_home_first():
    pm = plan_placement([5.0, 4.0, 1.0], n_slices=3, replicate_hot=2)
    for i in (0, 1):
        assert pm.replicated(i)
        ss = pm.slices_for(i)
        assert ss[0] == pm.home(i) and sorted(ss) == [0, 1, 2]
    assert pm.slices_for(2) == (pm.home(2),)


def test_plan_placement_single_slice_never_replicates():
    pm = plan_placement([5.0, 4.0, 1.0], n_slices=1, replicate_hot=2)
    assert pm.slices == ((0,), (0,), (0,))


def test_plan_placement_rejects_bad_inputs():
    with pytest.raises(AssertionError):
        plan_placement([1.0, 0.0], n_slices=2)           # non-positive size
    with pytest.raises(AssertionError):
        plan_placement([1.0], n_slices=2, traffic=[0.5, 0.5])
    with pytest.raises(AssertionError):
        PlacementMap(2, ((0,), (2,)))                    # slice out of range
    with pytest.raises(AssertionError):
        PlacementMap(2, ((0, 0),))                       # duplicate replica


def test_placement_summary_names_slices_and_replicas():
    pm = plan_placement([5.0, 4.0, 1.0], n_slices=2, replicate_hot=1)
    s = pm.summary(["a", "b", "c"])
    assert s["n_slices"] == 2
    assert s["replicated"] == ["a"]
    assert sorted(x for members in s["per_slice"].values()
                  for x in members) == ["a", "a", "b", "c"]


# ------------------------------------------------------------ streams


def test_stream_clock_accounting_and_dispatch():
    sc = StreamClock(3)
    sc.record(0, 2.0, tokens=100)
    sc.record(2, 0.5, tokens=10)
    assert sc.least_busy([0, 2]) == 2
    assert sc.least_busy([1, 2]) == 1                    # tie -> low index is
    sc.record(1, 0.5, tokens=10)                         # moot: 1 is idle
    assert sc.makespan_s == 2.0
    assert sc.total_busy_s == pytest.approx(3.0)
    sc.record_failure(2)
    s = sc.summary()
    assert s["flushes"] == [1, 1, 1] and s["failures"] == [0, 0, 1]
    assert s["tokens"] == [100, 10, 10]
    sc.reset()
    assert sc.makespan_s == 0.0 and sc.summary()["flushes"] == [0, 0, 0]


def test_scheduler_assigns_lane_slots_from_placement():
    pm = plan_placement([3.0, 2.0, 1.0], n_slices=2)
    sched = ExpertScheduler(n_experts=3, target=4, max_wait_s=1.0)
    assert all(lane.slot is None for lane in sched.lanes.values())
    sched.assign_slots(pm)
    for i in range(3):
        assert sched.lanes[i].slot == pm.home(i)
        assert sched.esc_lanes[i].slot == pm.home(i)


# ----------------------------------------------------- mesh validation


def test_host_mesh_error_names_the_xla_flag():
    need = 64 * 64
    if jax.device_count() >= need:                       # pragma: no cover
        pytest.skip("impossibly large host")
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_host_mesh(64, 64)
    with pytest.raises(ValueError, match=str(need)):
        make_host_mesh(64, 64)                           # says how many


def test_host_mesh_rejects_nonpositive_axes():
    with pytest.raises(ValueError):
        make_host_mesh(0, 1)


def test_engine_rejects_mesh_without_serving_axes(tiny_library,
                                                  router_params):
    mesh = jax.make_mesh((1,), ("x",))
    with pytest.raises(ValueError, match="data"):
        _engine(tiny_library, router_params, Clock(), mesh=mesh)


def test_engine_rejects_mismatched_placement(tiny_library, router_params):
    mesh = make_host_mesh(1, 1)
    with pytest.raises(ValueError):
        _engine(tiny_library, router_params, Clock(), mesh=mesh,
                placement=plan_placement([1.0, 1.0, 1.0], n_slices=2))
    with pytest.raises(ValueError):
        _engine(tiny_library, router_params, Clock(), mesh=mesh,
                placement=plan_placement([1.0, 1.0], n_slices=1))


# ------------------------------------------------ single-device parity


@pytest.mark.parametrize("use_kernel", [False, True])
def test_1x1_mesh_engine_is_bit_for_bit_meshless(tiny_library,
                                                 router_params,
                                                 use_kernel):
    """The acceptance gate: a (1, 1)-mesh engine serving the mixed-flag
    workload — with cascade escalations AND injected flush failures
    driving health-fallback reroutes — produces identical Results and
    identical EngineStats to the meshless engine."""
    reqs = _requests(96, seed=7, min_confidence=0.99, n_unique=64)
    hot = _hot_expert(tiny_library, router_params, reqs)
    outs, stats, engines = [], [], []
    for mesh in (None, make_host_mesh(1, 1)):
        clock = Clock()
        eng = _engine(tiny_library, router_params, clock, lane_target=8,
                      max_wait_s=1e9, use_kernel=use_kernel,
                      health=ExpertHealth(3, now_fn=clock),
                      mesh=mesh, replicate_hot=1)

        def stream():
            for i, r in enumerate(reqs):
                if i == 0:
                    # two failed flushes -> reroute + health penalty
                    eng.scheduler.inject_failures(hot, count=2)
                clock.advance(0.001)
                yield r

        out = list(eng.serve(stream()))
        assert len(out) == 96
        outs.append(sorted(out, key=lambda r: r.uid))
        stats.append(eng.stats.summary())
        engines.append(eng)
    for a, b in zip(*outs):
        assert _result_key(a) == _result_key(b)
    assert stats[0] == stats[1]
    # the traffic actually exercised the interesting paths
    assert stats[0]["cascade"]["escalations"] > 0
    assert stats[0]["fallback"]["reroutes"] > 0
    # mesh telemetry exists on the mesh engine only, outside the stats
    assert engines[0].mesh_summary() is None
    ms = engines[1].mesh_summary()
    assert ms["mesh"] == {"data": 1, "model": 1}
    assert ms["streams"]["streams"] == 1
    assert ms["streams"]["flushes"][0] > 0


def test_warm_mesh_compiles_every_variant(tiny_library, router_params):
    """warm_mesh covers the full (expert, replica device, bucket size)
    grid — dispatch can never hit a cold variant — and is a no-op on a
    meshless engine.  Warming charges no stream time."""
    assert _engine(tiny_library, router_params, Clock()).warm_mesh(32) == 0
    eng = _engine(tiny_library, router_params, Clock(), lane_target=8,
                  mesh=make_host_mesh(1, 1), replicate_hot=1)
    # 3 experts x 1 device x buckets {1, 2, 4, 8}
    assert eng.warm_mesh(32) == 12
    assert eng.streams.summary()["flushes"] == [0]
    assert eng.streams.makespan_s == 0.0


# ------------------------------------------------- multi-device parity


@multidevice
@pytest.mark.parametrize("use_kernel", [False, True])
def test_2x4_mesh_matches_single_device_choices_and_nll(tiny_library,
                                                        router_params,
                                                        use_kernel):
    """On 8 virtual CPU devices a (2, 4) mesh — data-parallel routing,
    experts spread over 4 slices with the hottest replicated — must
    agree with the meshless engine on every routing choice exactly and
    on every measured per-request NLL to float tolerance."""
    # mixed cascade thresholds: every 4th request escalates (constant
    # uncertainty prior -> conf 0.5 < 0.99), the rest keep the router's
    # first pick so traffic spreads over the library
    reqs = [dataclasses.replace(r, min_confidence=0.99 if i % 4 == 0
                                else 0.0)
            for i, r in enumerate(_requests(128, seed=11, n_unique=96))]
    outs, engines = [], []
    for mesh in (None, make_host_mesh(2, 4)):
        clock = Clock()
        eng = _engine(tiny_library, router_params, clock, lane_target=8,
                      max_wait_s=1e9, use_kernel=use_kernel,
                      mesh=mesh, replicate_hot=1)
        out = list(eng.serve(iter(reqs)))
        assert len(out) == 128
        outs.append(sorted(out, key=lambda r: r.uid))
        engines.append(eng)
    for a, b in zip(*outs):
        assert a.expert == b.expert
        assert a.cascade_depth == b.cascade_depth
        if a.loss is not None or b.loss is not None:
            np.testing.assert_allclose(b.loss, a.loss, rtol=1e-5)
    # flush accounting: every flush landed in some device stream, and
    # the placement actually spread work over multiple streams
    eng = engines[1]
    st = eng.mesh_summary()["streams"]
    assert sum(st["flushes"]) == sum(eng.stats.flushes.values())
    # distinct home slices -> flushes land in multiple device streams
    # (busy_s stays 0.0 under the fake clock, so count flushes instead)
    assert sum(1 for f in st["flushes"] if f > 0) > 1
    assert eng.placement.n_slices == 4
    assert len(eng.stats.per_expert) > 1
    assert eng.stats.escalations > 0


@multidevice
def test_mesh_fallback_parity(tiny_library, router_params):
    """Failure-injection traffic (reroutes via health fallback) routes
    identically on the (2, 4) mesh, and failed flushes are charged to
    the failing expert's device streams."""
    reqs = _requests(64, seed=5)
    hot = _hot_expert(tiny_library, router_params, reqs)
    hot_name = tiny_library.experts[hot].name
    outs, engines = [], []
    for mesh in (None, make_host_mesh(2, 4)):
        clock = Clock()
        eng = _engine(tiny_library, router_params, clock, lane_target=8,
                      max_wait_s=1e9,
                      health=ExpertHealth(3, now_fn=clock),
                      mesh=mesh, replicate_hot=1)

        def stream():
            for i, r in enumerate(reqs):
                if i == 0:
                    eng.scheduler.inject_failures(hot)   # fail every flush
                yield r

        out = list(eng.serve(stream()))
        assert len(out) == 64
        assert all(not r.failed for r in out)
        assert all(r.expert != hot_name for r in out)
        outs.append(sorted(out, key=lambda r: r.uid))
        engines.append(eng)
    for a, b in zip(*outs):
        assert a.expert == b.expert
        assert a.fallback_depth == b.fallback_depth
    st = engines[1].mesh_summary()["streams"]
    assert sum(st["failures"]) >= 1                      # charged somewhere
