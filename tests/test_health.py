"""ExpertHealth: the circuit-breaker state machine and its signals.

Pure host-side unit tests on a deterministic injected clock — no JAX,
no engine.  The engine-level integration (fallback routing, failure
re-routes) lives in tests/test_fallback.py.
"""

import numpy as np
import pytest

from repro.serving import ExpertHealth


class Clock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


@pytest.fixture
def clock():
    return Clock()


@pytest.fixture
def health(clock):
    return ExpertHealth(3, cooldown_s=10.0, now_fn=clock)


def test_fresh_tracker_all_available(health):
    assert health.healthy_mask().all()
    assert health.available_mask().all()
    for i in range(3):
        assert health.healthy(i) and not health.overloaded(i)


def test_single_failure_trips_breaker(health):
    """failure_alpha=0.5 means one failure lands the EWMA exactly on the
    0.5 threshold — immediately unhealthy."""
    health.record_failure(1)
    assert not health.healthy(1)
    assert health.healthy(0) and health.healthy(2)
    assert list(health.available_mask()) == [True, False, True]
    assert health.states[1].failures == 1


def test_cooldown_holds_breaker_open(health, clock):
    """Even after successful flushes decay the failure EWMA below
    threshold, the expert stays unhealthy until cooldown_s has passed
    since the last failure."""
    health.record_failure(0)
    for _ in range(4):
        health.observe_flush(0, 0.01, ok=True)
    assert health.states[0].failure_ewma < health.fail_threshold
    clock.t = 9.9
    assert not health.healthy(0)          # still inside the cooldown
    clock.t = 10.1
    assert health.healthy(0)              # cooldown expired, EWMA low


def test_breaker_reopens_on_next_failure(health, clock):
    health.record_failure(0)
    clock.t = 50.0
    for _ in range(4):
        health.observe_flush(0, 0.01, ok=True)
    assert health.healthy(0)
    health.record_failure(0)              # half-open -> open again
    assert not health.healthy(0)
    clock.t = 59.9
    assert not health.healthy(0)


def test_persistent_failures_keep_ewma_high(health, clock):
    for _ in range(5):
        health.record_failure(2)
    clock.t = 1e6                         # far past any cooldown
    assert not health.healthy(2)          # EWMA alone keeps it open
    assert health.states[2].failure_ewma > health.fail_threshold


def test_force_down_and_release(health):
    health.force_down(1)
    assert not health.healthy(1) and not health.available(1)
    health.force_down(1, down=False)
    assert health.healthy(1)


def test_overload_is_depth_ewma_threshold(clock):
    h = ExpertHealth(2, overload_depth=8.0, depth_alpha=1.0, now_fn=clock)
    h.observe_lane_depth(0, 10)
    assert h.overloaded(0) and not h.overloaded(1)
    # overloaded but not failed: unhealthy is False, available is False
    assert h.healthy(0) and not h.available(0)
    # idle observations decay the EWMA back under the threshold
    h.observe_lane_depth(0, 0)
    assert not h.overloaded(0) and h.available(0)


def test_ewma_arithmetic(clock):
    h = ExpertHealth(1, depth_alpha=0.5, latency_alpha=0.5, now_fn=clock)
    h.observe_lane_depth(0, 4)
    h.observe_lane_depth(0, 8)
    assert h.states[0].depth_ewma == pytest.approx(0.5 * 2.0 + 0.5 * 8.0)
    h.observe_flush(0, 0.1)
    h.observe_flush(0, 0.3)
    assert h.states[0].latency_ewma_s == pytest.approx(0.5 * 0.05 + 0.15)
    assert h.states[0].flushes == 2


def test_failed_flush_does_not_pollute_latency(health):
    health.observe_flush(0, 0.2, ok=True)
    lat = health.states[0].latency_ewma_s
    health.observe_flush(0, 99.0, ok=False)
    assert health.states[0].latency_ewma_s == lat
    assert health.states[0].flushes == 1
    assert health.states[0].failures == 1


def test_masks_are_bool_arrays(health):
    health.record_failure(2)
    hm, am = health.healthy_mask(), health.available_mask()
    assert hm.dtype == np.bool_ and am.dtype == np.bool_
    assert hm.shape == am.shape == (3,)
    assert not hm[2] and not am[2]


def test_snapshot_shape_and_keys(health):
    health.record_failure(1)
    health.observe_lane_depth(0, 3)
    snap = health.snapshot()
    assert len(snap) == 3
    for entry in snap:
        assert set(entry) == {"healthy", "overloaded", "depth_ewma",
                              "latency_ewma_s", "failure_ewma", "flushes",
                              "failures", "forced_down"}
    assert snap[1]["healthy"] is False
    assert snap[0]["depth_ewma"] > 0


def test_constructor_validation():
    with pytest.raises(AssertionError):
        ExpertHealth(0)
    with pytest.raises(AssertionError):
        ExpertHealth(2, failure_alpha=0.0)
    with pytest.raises(AssertionError):
        ExpertHealth(2, depth_alpha=1.5)
