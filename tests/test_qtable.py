"""Q-table construction against a NumPy oracle.

``build_q_table`` is the supervision source for the router (and now,
indirectly, the ground truth every drift/adaptation gate measures
against), so its per-prompt masked NLL / masked accuracy math is checked
here against an independent float64 NumPy implementation over the
experts' actual logits, plus the domain-concatenation ordering contract
across batches.  Deliberately hypothesis-free.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qtable import build_q_table, mlm_accuracy
from repro.data.batching import mlm_batch
from repro.models.model import forward


def _batches(rng, n_batches=3, batch=6, seq=24, vocab=64):
    """MLM batches with distinct per-batch domain labels."""
    out = []
    for bi in range(n_batches):
        toks = rng.integers(4, vocab, size=(batch, seq)).astype(np.int32)
        mb = mlm_batch(toks, rng, 0.25, vocab)
        mb["domain"] = np.full(batch, bi, np.int64)
        out.append(mb)
    return out


def _numpy_oracle(library, batches):
    """Float64 reimplementation of the per-prompt metrics: masked token
    NLL via stable log-softmax and masked top-1 accuracy, straight from
    each expert's logits."""
    losses, accs = [], []
    for e in library.experts:
        el, ea = [], []
        for b in batches:
            jb = {"tokens": jnp.asarray(b["tokens"]),
                  "targets": jnp.asarray(b["targets"]),
                  "mask": jnp.asarray(b["mask"])}
            logits = np.asarray(
                forward(e.params, e.cfg, jb, mode="train",
                        remat=False)[0]).astype(np.float64)
            targets, mask = b["targets"], b["mask"].astype(np.float64)
            m = logits.max(-1, keepdims=True)
            logz = (m[..., 0] + np.log(np.exp(logits - m).sum(-1)))
            B, S = targets.shape
            gold = logits[np.arange(B)[:, None], np.arange(S)[None, :],
                          targets]
            denom = np.maximum(mask.sum(-1), 1.0)
            el.append(((logz - gold) * mask).sum(-1) / denom)
            pred = logits.argmax(-1)
            ea.append(((pred == targets) * mask).sum(-1) / denom)
        losses.append(np.concatenate(el))
        accs.append(np.concatenate(ea))
    return np.stack(losses, axis=1), np.stack(accs, axis=1)


@pytest.fixture(scope="module")
def qtable_setup(tiny_library):
    rng = np.random.default_rng(42)
    batches = _batches(rng)
    q = build_q_table(tiny_library, batches)
    return batches, q


def test_qtable_shapes_and_domain_order(tiny_library, qtable_setup):
    batches, q = qtable_setup
    N = sum(len(b["tokens"]) for b in batches)
    M = len(tiny_library)
    assert q["loss"].shape == (N, M)
    assert q["acc"].shape == (N, M)
    # domains concatenate in batch order, rows aligned with prompts
    np.testing.assert_array_equal(
        q["domain"], np.concatenate([b["domain"] for b in batches]))


def test_qtable_matches_numpy_oracle(tiny_library, qtable_setup):
    batches, q = qtable_setup
    loss_ref, acc_ref = _numpy_oracle(tiny_library, batches)
    np.testing.assert_allclose(q["loss"], loss_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(q["acc"], acc_ref, rtol=1e-5, atol=1e-6)
    # sanity: untrained-expert NLL sits near ln(vocab)
    assert 0.5 * np.log(64) < q["loss"].mean() < 2.0 * np.log(64)


def test_qtable_batch_rows_are_independent(tiny_library, qtable_setup):
    """Rows for one batch equal building the table on that batch alone:
    concatenation across batches neither reorders nor mixes prompts."""
    batches, q = qtable_setup
    n0 = len(batches[0]["tokens"])
    q1 = build_q_table(tiny_library, [batches[1]])
    np.testing.assert_array_equal(
        q["loss"][n0:n0 + len(batches[1]["tokens"])], q1["loss"])
    np.testing.assert_array_equal(
        q["acc"][n0:n0 + len(batches[1]["tokens"])], q1["acc"])
    np.testing.assert_array_equal(q1["domain"], batches[1]["domain"])


def test_qtable_all_zero_mask_row_guard(tiny_library):
    """A prompt with no masked positions reduces to loss 0 / acc 0 via
    the max(denominator, 1) guard instead of dividing by zero."""
    rng = np.random.default_rng(7)
    b = _batches(rng, n_batches=1, batch=4)[0]
    b["mask"][2] = 0
    q = build_q_table(tiny_library, [b])
    assert (q["loss"][2] == 0.0).all()
    assert (q["acc"][2] == 0.0).all()
    assert np.isfinite(q["loss"]).all()
    # the other rows are untouched by the degenerate one
    assert (q["loss"][[0, 1, 3]] > 0).all()


def test_mlm_accuracy_selects_per_prompt_choices(tiny_library,
                                                 qtable_setup):
    _, q = qtable_setup
    choices = np.argmax(q["acc"], axis=1)
    expected = q["acc"].max(axis=1).mean()
    assert mlm_accuracy(q, choices) == pytest.approx(expected)
    # routing everyone to expert 0 averages column 0
    zeros = np.zeros(len(q["acc"]), np.int64)
    assert mlm_accuracy(q, zeros) == pytest.approx(q["acc"][:, 0].mean())
