"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture family runs one forward/train step on CPU with
correct output shapes and no NaNs, plus prefill+decode for decoders."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import (count_params, decode_step,
                          init_model, lm_loss, prefill)

ARCHS = list_archs()

# architectures whose reduced train step still exceeds a minute on CPU
# (deep scan/MoE stacks): their full train-step smoke is `slow`, the
# cheaper shape/decode smokes below still run in the fast loop
_HEAVY = {"jamba_v01_52b", "xlstm_13b"}
ARCHS_TRAIN = [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a
               for a in ARCHS]


def _batch(cfg, key, B=2, S=32):
    if cfg.is_encoder or cfg.family in ("vlm", "audio"):
        ke, kt = jax.random.split(key)
        return {
            "embeds": jax.random.normal(ke, (B, S, cfg.d_model), jnp.float32),
            "targets": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
            "mask": jnp.ones((B, S), jnp.int32),
        }
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {"tokens": toks}


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_constraints(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 8 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ARCHS_TRAIN)
def test_forward_train_step(arch, key):
    cfg = get_config(arch).reduced()
    params, logical = init_model(key, cfg)
    assert count_params(params) > 0
    batch = _batch(cfg, key)
    loss, metrics = jax.jit(lambda p, b: lm_loss(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: NaN loss"
    # one actual optimizer step
    from repro.optim import adamw_init, adamw_update
    g = jax.grad(lambda p: lm_loss(p, cfg, batch)[0])(params)
    assert all(jnp.isfinite(x).all() for x in jax.tree.leaves(g)), arch
    p2, _ = adamw_update(params, g, adamw_init(params), lr=1e-3)
    l2, _ = lm_loss(p2, cfg, batch)
    assert bool(jnp.isfinite(l2))


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "hubert_xlarge"])
def test_prefill_decode_shapes(arch, key):
    cfg = get_config(arch).reduced()
    B, S = 2, 32
    batch = _batch(cfg, key, B, S)
    batch.pop("targets", None), batch.pop("mask", None)
    params, _ = init_model(key, cfg)
    logits, state = jax.jit(
        lambda p, b: prefill(p, cfg, b, cache_capacity=S + 4))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    lg, state2 = jax.jit(
        lambda p, b, st: decode_step(p, cfg, b, st, S))(
        params, {"tokens": tok}, state)
    assert lg.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(lg.astype(jnp.float32)).all()), arch


# MoE archs are excluded: expert capacity C = ceil(T/E*cf*k) depends on
# sequence length, so token dropping differs between an S-token and an
# (S+1)-token prefill and exact logit equality is not expected.
@pytest.mark.parametrize("arch", ["tinyllama_11b", "xlstm_13b",
                                  "starcoder2_15b", "gemma3_4b"])
def test_decode_matches_prefill_next_token(arch, key):
    """Greedy continuation from prefill state == running prefill over S+1."""
    cfg = get_config(arch).reduced()
    B, S = 1, 16
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    full_logits, _ = prefill(params := init_model(key, cfg)[0], cfg,
                             {"tokens": toks})
    pre_logits, state = prefill(params, cfg, {"tokens": toks[:, :S]},
                                cache_capacity=S + 1)
    dec_logits, _ = decode_step(params, cfg, {"tokens": toks[:, S:]},
                                state, S)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits[:, S], np.float32), atol=2e-2, rtol=1e-2)
