"""Shared fixtures. NOTE: tests must see the single real CPU device —
the 512-device XLA flag belongs ONLY to launch/dryrun.py subprocesses."""

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def corpus():
    from repro.data.corpus import DomainCorpus
    return DomainCorpus(vocab_size=512, seed=0)
