"""Shared fixtures. NOTE: tests must see the single real CPU device —
the 512-device XLA flag belongs ONLY to launch/dryrun.py subprocesses."""

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def corpus():
    from repro.data.corpus import DomainCorpus
    return DomainCorpus(vocab_size=512, seed=0)


@pytest.fixture(scope="session")
def tiny_library():
    """3 untrained tiny experts (routing still well-defined) — the shared
    library for serving/scheduler tests."""
    from repro.core.library import ExpertSpec, ModelLibrary, _enc
    from repro.models.model import count_params, init_model
    lib = ModelLibrary([
        ExpertSpec("small", _enc("small", 1, 32, 2, 64, 64), {}, 0.5),
        ExpertSpec("mid", _enc("mid", 1, 48, 2, 96, 64), {}, 0.5),
        ExpertSpec("big", _enc("big", 2, 64, 2, 128, 64), {}, 0.9),
    ])
    for i, e in enumerate(lib.experts):
        e.params, _ = init_model(jax.random.PRNGKey(i), e.cfg)
        e.n_params = count_params(e.params)
    return lib
