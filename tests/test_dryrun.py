"""Dry-run machinery tests.

The full 40-pair x 2-mesh matrix runs via ``python -m repro.launch.dryrun``
(results under experiments/dryrun).  Here we (a) verify the HLO collective
parser on known text, (b) verify roofline math, and (c) spot-check one
real lower+compile on the production mesh in a subprocess (which is the
only place the 512-device XLA flag may be set).
"""

import json
import os
import subprocess
import sys

import pytest

from repro.launch import hlo_stats
from repro.launch.roofline import Roofline

REPO = os.path.join(os.path.dirname(__file__), "..")


def test_collective_parser():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024]{1,0} %x), replica_groups={}
  %ar.1 = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%add
  %rs = f32[8,32]{1,0} reduce-scatter(f32[64,32]{1,0} %z), dimensions={0}
  %cp = u32[4]{0} collective-permute(u32[4]{0} %w), source_target_pairs={{0,1}}
  %a2a = bf16[2,8]{1,0} all-to-all(bf16[2,8]{1,0} %v), dimensions={0}
  %ar-start = f32[128]{0} all-reduce-start(f32[128]{0} %q), to_apply=%add
  %ar-done = f32[128]{0} all-reduce-done(f32[128]{0} %ar-start)
"""
    stats = hlo_stats.collective_stats(hlo)
    assert stats["all-gather"]["count"] == 1
    assert stats["all-gather"]["bytes"] == 16 * 1024 * 2
    assert stats["all-reduce"]["count"] == 2           # ar.1 + ar-start
    assert stats["reduce-scatter"]["bytes"] == 8 * 32 * 4
    assert stats["collective-permute"]["count"] == 1
    assert stats["all-to-all"]["bytes"] == 2 * 8 * 2
    assert stats["total_count"] == 6


def test_roofline_terms():
    rl = Roofline(flops=197e12, hbm_bytes=819e9, collective_bytes=50e9)
    assert abs(rl.t_compute - 1.0) < 1e-9
    assert abs(rl.t_memory - 1.0) < 1e-9
    assert abs(rl.t_collective - 1.0) < 1e-9
    rl2 = Roofline(flops=1e12, hbm_bytes=819e9, collective_bytes=0)
    assert rl2.dominant == "memory"


def test_applicability_matrix():
    from repro.configs import get_config
    from repro.launch.specs import applicable
    from repro.models.common import INPUT_SHAPES
    ok, _ = applicable(get_config("hubert-xlarge"), INPUT_SHAPES["decode_32k"])
    assert not ok
    ok, _ = applicable(get_config("tinyllama-1.1b"), INPUT_SHAPES["long_500k"])
    assert not ok
    ok, _ = applicable(get_config("xlstm-1.3b"), INPUT_SHAPES["long_500k"])
    assert ok
    ok, _ = applicable(get_config("gemma3-4b"), INPUT_SHAPES["long_500k"])
    assert ok  # sliding-window qualifies
    ok, _ = applicable(get_config("grok-1-314b"), INPUT_SHAPES["train_4k"])
    assert ok


@pytest.mark.slow
def test_dryrun_subprocess_compiles_one_pair():
    """One real (arch x shape) lower+compile on the 16x16 production mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "qwen1.5-0.5b", "--shape", "decode_32k", "--mesh", "pod",
         "--tag", "pytest"],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "[OK  ]" in out.stdout
    path = os.path.join(REPO, "experiments", "dryrun",
                        "qwen1.5-0.5b_decode_32k_pod_pytest.json")
    with open(path) as f:
        rec = json.load(f)
    assert rec["status"] == "OK"
    assert rec["n_chips"] == 256
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert rec["memory"]["peak_bytes_per_device"] > 0
