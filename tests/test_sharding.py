"""Sharding-rule properties (hypothesis) + mesh/spec construction.
Deterministic tests run everywhere; only the property-based tests skip
when hypothesis is absent."""

import jax
import numpy as np
import pytest

from hyputil import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.sharding import DEFAULT_RULES, logical_to_spec


@pytest.fixture(scope="module")
def mesh2():
    return jax.make_mesh((1, 1), ("data", "model"))


LOGICALS = ["batch", "embed", "mlp", "heads", "kv_heads", "vocab", "expert",
            "cache", "head_dim", None]


@given(axes=st.lists(st.sampled_from(LOGICALS), min_size=1, max_size=4),
       dims=st.lists(st.integers(min_value=1, max_value=64), min_size=4,
                     max_size=4))
@settings(max_examples=60, deadline=None)
def test_spec_properties(axes, dims, mesh2):
    dims = dims[:len(axes)]
    spec = logical_to_spec(mesh2, axes, dims, DEFAULT_RULES)
    assert len(spec) <= len(axes)
    # every mesh axis used at most once
    used = [a for a in jax.tree.leaves(tuple(spec)) if a is not None]
    flat = []
    for u in used:
        flat += list(u) if isinstance(u, tuple) else [u]
    assert len(flat) == len(set(flat))


def test_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # with axis sizes 1, everything divides -> spec assigns axes
    spec = logical_to_spec(mesh, ("batch", "seq"), (8, 16), DEFAULT_RULES)
    assert spec == P("data", None)


def test_divisibility_respected_on_simulated_mesh():
    """Pure-math check against a simulated 16x16 mesh via a fake mesh shape."""

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    # kv_heads = 8 is not divisible by 16 -> replicated
    spec = logical_to_spec(FakeMesh(), ("batch", "cache", "kv_heads",
                                        "head_dim"),
                           (128, 32768, 8, 128), DEFAULT_RULES)
    assert spec == P("data", "model", None, None)
    # vocab 504 (hubert) replicated; embed 1280 sharded over data
    spec2 = logical_to_spec(FakeMesh(), ("vocab", "embed"), (504, 1280),
                            DEFAULT_RULES)
    assert spec2 == P(None, "data")
    # MoE expert dim 8 on model fails -> capacity takes data
    spec3 = logical_to_spec(FakeMesh(), ("expert", "capacity", "act_embed"),
                            (8, 81920, 6144), DEFAULT_RULES)
    assert spec3 == P(None, "data", None)
    # jamba: 16 experts divide -> expert on model
    spec4 = logical_to_spec(FakeMesh(), ("expert", "embed", "mlp"),
                            (16, 4096, 14336), DEFAULT_RULES)
    assert spec4 == P("model", "data", None) or spec4 == P("model", "data", None)


def test_multipod_rules_tuple_axes():
    from repro.sharding import MULTIPOD_RULES

    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}

    spec = logical_to_spec(FakeMesh(), ("batch", "seq"), (256, 4096),
                           MULTIPOD_RULES)
    assert spec == P(("pod", "data"), None)
    # batch=1 cannot shard -> fully replicated
    spec2 = logical_to_spec(FakeMesh(), ("batch", "seq"), (1, 4096),
                            MULTIPOD_RULES)
    assert spec2 == P(None, None)
    # batch=16: pod*data=32 fails, prefix (pod,) = 2 works
    spec3 = logical_to_spec(FakeMesh(), ("batch", "seq"), (16, 4096),
                            MULTIPOD_RULES)
    assert spec3 == P("pod", None)


def test_shard_act_noop_outside_context(key):
    from repro.sharding import shard_act
    x = jax.numpy.ones((4, 4))
    y = shard_act(x, ("batch", "seq"))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_production_mesh_requires_devices():
    """make_production_mesh needs 256/512 devices; on 1-CPU it must raise
    cleanly (the dry-run subprocess sets the device-count flag)."""
    from repro.launch.mesh import make_production_mesh
    if jax.device_count() < 256:
        with pytest.raises(ValueError):
            make_production_mesh()
