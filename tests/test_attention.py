import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models.common import AttnConfig, ModelConfig


def _cfg(heads=4, kv=2, causal=True, window=0, qkv_bias=False, theta=10000.0):
    return ModelConfig(
        name="t", family="dense", num_layers=1, d_model=64, num_heads=heads,
        num_kv_heads=kv, d_ff=128, vocab_size=64,
        attn=AttnConfig(rope_theta=theta, causal=causal,
                        sliding_window=window,
                        window_pattern="all_local" if window else "all_global",
                        qkv_bias=qkv_bias),
        dtype="float32")


def test_causal_masking(key):
    """Future tokens must not influence earlier outputs."""
    cfg = _cfg()
    p, _ = A.init_attention(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 8, 64))
    pos = jnp.arange(8)[None]
    y1, _ = A.attend_full(p, x, cfg, pos)
    x2 = x.at[:, -1].set(99.0)
    y2, _ = A.attend_full(p, x2, cfg, pos)
    np.testing.assert_allclose(np.asarray(y1[:, :-1]), np.asarray(y2[:, :-1]),
                               atol=1e-5)
    assert not np.allclose(np.asarray(y1[:, -1]), np.asarray(y2[:, -1]))


def test_bidirectional_sees_future(key):
    cfg = _cfg(causal=False)
    p, _ = A.init_attention(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 8, 64))
    pos = jnp.arange(8)[None]
    y1, _ = A.attend_full(p, x, cfg, pos)
    y2, _ = A.attend_full(p, x.at[:, -1].set(9.0), cfg, pos)
    assert not np.allclose(np.asarray(y1[:, 0]), np.asarray(y2[:, 0]))


def test_sliding_window_equals_full_for_short_seq(key):
    cfg_w = _cfg(window=16)
    p, _ = A.init_attention(key, cfg_w, jnp.float32)
    x = jax.random.normal(key, (2, 8, 64))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    y_w, _ = A.attend_full(p, x, cfg_w, pos, window=16)
    y_f, _ = A.attend_full(p, x, cfg_w, pos, window=0)
    np.testing.assert_allclose(np.asarray(y_w), np.asarray(y_f), atol=1e-5)


def test_sliding_window_limits_context(key):
    cfg = _cfg(window=4)
    p, _ = A.init_attention(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 16, 64))
    pos = jnp.arange(16)[None]
    y1, _ = A.attend_full(p, x, cfg, pos, window=4)
    y2, _ = A.attend_full(p, x.at[:, 0].set(50.0), cfg, pos, window=4)
    # token 10 is outside window of token 0 -> unaffected
    np.testing.assert_allclose(np.asarray(y1[:, 10:]), np.asarray(y2[:, 10:]),
                               atol=1e-4)


def test_chunked_matches_unchunked(key):
    cfg = _cfg()
    p, _ = A.init_attention(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 64, 64))
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    q, k, v = A._project_qkv(p, x, cfg, pos)
    kr, vr = A._repeat_kv(k, v, cfg.num_heads)

    def bias_fn(off, qn):
        qi = jnp.arange(qn)[:, None] + off
        kj = jnp.arange(64)[None, :]
        return jnp.where(kj <= qi, 0.0, A.NEG_INF)

    o_small = A._sdpa_chunked(q, kr, vr, bias_fn, q_chunk=16)
    o_full = A._sdpa_chunked(q, kr, vr, bias_fn, q_chunk=64)
    np.testing.assert_allclose(np.asarray(o_small), np.asarray(o_full),
                               atol=1e-5)


@pytest.mark.parametrize("window", [0, 8])
def test_decode_matches_prefill(key, window):
    """Prefill then one decode step == full forward over S+1 tokens."""
    cfg = _cfg(window=window)
    p, _ = A.init_attention(key, cfg, jnp.float32)
    S = 24
    x = jax.random.normal(key, (1, S + 1, 64))
    pos = jnp.arange(S + 1)[None]
    y_full, _ = A.attend_full(p, x, cfg, pos, window=window)

    y_pre, kv = A.attend_full(p, x[:, :S], cfg, pos[:, :S], window=window)
    cache = A.prefill_cache_from_kv(kv[0], kv[1], window, jnp.float32,
                                    capacity=S + 1)
    y_dec, _ = A.attend_decode(p, x[:, S:], cache, S, cfg, pos[:, S:],
                               window=window)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_full[:, S]), atol=1e-4)


def test_ring_buffer_wraps(key):
    """Decoding past the window keeps exactly the last W tokens."""
    cfg = _cfg(window=8)
    p, _ = A.init_attention(key, cfg, jnp.float32)
    W, S = 8, 20
    x = jax.random.normal(key, (1, S + 1, 64))
    pos = jnp.arange(S + 1)[None]
    y_full, _ = A.attend_full(p, x, cfg, pos, window=W)

    cache = A.init_kv_cache(1, W, cfg, jnp.float32)
    y_dec = None
    for t in range(S + 1):
        y_dec, cache = A.attend_decode(p, x[:, t:t + 1], cache, t, cfg,
                                       pos[:, t:t + 1], window=W)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_full[:, S]), atol=1e-4)


def test_layer_window_patterns():
    cfg = _cfg(window=128)
    cfg = dataclasses.replace(
        cfg, attn=dataclasses.replace(cfg.attn, window_pattern="gemma",
                                      global_every=6))
    ws = [A.layer_window(cfg, i) for i in range(12)]
    assert ws[5] == 0 and ws[11] == 0
    assert all(w == 128 for i, w in enumerate(ws) if i % 6 != 5)
