"""§Perf hillclimb driver.

Three pairs selected from the baseline roofline table:
  * xlstm_train   — worst roofline fraction (t_mem 6172s: the sequential
                    mLSTM/sLSTM scans round-trip the matrix memory C
                    through HBM every timestep)
  * jamba_decode  — most collective-bound pair (t_coll > t_mem)
  * qwen_decode   — most representative of the paper's technique (Tryage
                    routes to small experts; decode latency IS the serving
                    cost the router trades off)

Each variant is one hypothesis -> change -> re-lower -> re-analyse cycle;
results land in experiments/dryrun/*_<tag>.json next to the baselines.

Usage:
  PYTHONPATH=src python scripts/hillclimb.py [xlstm_train|jamba_decode|qwen_decode|all]
"""

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.dryrun import run_one
from repro.launch.steps import PerfKnobs

# name -> (arch, shape, [(tag, knobs, setup_fn, hypothesis)])
EXPERIMENTS = {
    "xlstm_train": (
        "xlstm-1.3b", "train_4k", [
            ("h1_chunkwise",
             PerfKnobs(microbatch=4, unit_group=2),
             "mlstm_chunkwise",
             "per-timestep mLSTM scan writes the (B,H,dh,dh) matrix memory "
             "C (1024x1024 f32 per head) to HBM 4096 times per layer; the "
             "chunkwise-parallel closed form (same math as the Pallas "
             "kernel) updates C once per 64-step chunk -> predict ~50-60x "
             "reduction of the mLSTM share of the memory term"),
            ("h2_chunkwise_mb1",
             PerfKnobs(microbatch=1, unit_group=2),
             "mlstm_chunkwise",
             "with traffic collapsed, drop grad-accumulation (microbatch "
             "4 -> 1) to stop re-reading weights 4x; watch peak memory"),
        ]),
    "jamba_decode": (
        "jamba-v0.1-52b", "decode_32k", [
            ("h1_nofsdp",
             PerfKnobs(rule_overrides={"embed": None}),
             None,
             "decode has no optimizer state, so FSDP ('embed'->data) "
             "sharding only forces an all-gather of every weight each "
             "step; model-only sharding (52B*2B/16 = 6.5GB/chip weights) "
             "should remove most collective bytes"),
            ("h2_nofsdp_cache_batch",
             PerfKnobs(rule_overrides={"embed": None, "cache": None}),
             None,
             "additionally keep the KV cache unsharded on seq (batch+kv "
             "sharding only) to kill the involuntary-remat copies at the "
             "cache update"),
            ("h3_cache_only",
             PerfKnobs(rule_overrides={"cache": None}),
             None,
             "h1 exceeded HBM (replicated 45B of MoE weights = +5.6GB/chip "
             "plus gathered transients); keep FSDP for weights and only "
             "fix the cache-update resharding (jamba kv=8 < 16 so the "
             "cache stays batch-sharded, 8.6GB/chip — fits)"),
            ("h4_pure_tp",
             PerfKnobs(rule_overrides={
                 "embed": None, "mlp": ("model", "data"),
                 "heads": ("model", "data"), "kv_heads": ("model", "data"),
                 "inner": ("model", "data"), "vocab": ("model", "data"),
                 "capacity": None}),
             None,
             "decode re-gathers FSDP weights every token; instead shard "
             "weights 256-way (pure TP over both axes: d_ff 14336 and "
             "inner 8192 divide 256) so weights never move and the only "
             "collectives are psums over (128, d) activations — predict "
             "collective term drops by ~weight-bytes/activation-bytes "
             "(~100x on the MoE layers) while weights stay 0.4GB/chip"),
        ]),
    "qwen_decode": (
        "qwen1.5-0.5b", "decode_32k", [
            ("h1_kvheads",
             PerfKnobs(rule_overrides={"cache": None}),
             None,
             "cache seq dim sharded over 'model' makes the per-layer "
             "softmax a cross-chip contraction and the cache update a "
             "resharding copy; qwen1.5 has 16 kv heads == mesh axis, so "
             "sharding kv_heads instead keeps attention chip-local"),
            ("h2_kvheads_nofsdp",
             PerfKnobs(rule_overrides={"cache": None, "embed": None}),
             None,
             "0.5B weights are 1GB bf16: replicate over 'data' (shard "
             "model-only) to remove decode weight all-gathers"),
        ]),
}


def _setup(flag):
    if flag == "mlstm_chunkwise":
        from repro.models import ssm
        ssm.MLSTM_DEFAULT_IMPL = "chunkwise"
    elif flag is None:
        from repro.models import ssm
        ssm.MLSTM_DEFAULT_IMPL = "xla"


def main():
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    names = list(EXPERIMENTS) if which == "all" else [which]
    for name in names:
        arch, shape, variants = EXPERIMENTS[name]
        _setup(None)
        base = run_one(arch, shape, "pod", save=False, tag="")
        rl0 = base["roofline"]
        print(f"\n=== {name}: {arch} x {shape} (baseline) ===", flush=True)
        print(f"  dom={rl0['dominant']} t_comp={rl0['t_compute_s']:.4f} "
              f"t_mem={rl0['t_memory_s']:.4f} t_coll={rl0['t_collective_s']:.4f} "
              f"peak={base['memory']['peak_bytes_per_device']/2**30:.2f}GiB")
        dom0 = rl0["dominant"]
        key = {"compute": "t_compute_s", "memory": "t_memory_s",
               "collective": "t_collective_s"}[dom0]
        for tag, knobs, setup, hyp in variants:
            _setup(setup)
            rec = run_one(arch, shape, "pod", knobs=knobs, save=True, tag=tag)
            _setup(None)
            if rec["status"] != "OK":
                print(f"  [{tag}] FAILED: {rec.get('error','')[:200]}",
                      flush=True)
                continue
            rl = rec["roofline"]
            delta = (rl[key] - rl0[key]) / max(rl0[key], 1e-12)
            print(f"  [{tag}] dom={rl['dominant']} "
                  f"t_comp={rl['t_compute_s']:.4f} t_mem={rl['t_memory_s']:.4f} "
                  f"t_coll={rl['t_collective_s']:.4f} "
                  f"peak={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
                  f"| dominant({dom0}) delta {delta:+.1%}", flush=True)


if __name__ == "__main__":
    main()
