"""Mixed-domain evaluation — the paper's core argument for perceptive
routing over model-card matching:

  "users often seek to analyze data streams that contain information from
   multiple domains ... even a file of python code might contain code and
   comments; a clinical trial report will contain biomedical and
   regulatory data."

On PURE single-domain prompts a surface-statistics router (the
Gorilla-class keyword baseline) can match the learned router, because our
synthetic domains are perfectly separable by private-vocabulary counts.
This script builds MIXED prompts (two domains concatenated at a random
split) and re-evaluates: the keyword router must commit to the majority
domain's expert, while Tryage predicts realized per-prompt loss.

Reuses the cached experiment artifacts; writes
experiments/tryage/mixed_results.json.
"""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import baselines as bl
from repro.core import experiment as ex
from repro.core.qtable import build_q_table, mlm_accuracy
from repro.core.router import predict_losses
from repro.data.batching import mlm_batch
from repro.data.corpus import DOMAINS

art = ex.load_artifacts()
lib, rp, rc, corpus = (art["library"], art["router_params"], art["rc"],
                       art["corpus"])

rng = np.random.default_rng(42)
N, S = 512, 128
halves = []
pair_list = []
for i in range(N):
    d1, d2 = rng.choice(len(DOMAINS), size=2, replace=False)
    cut = rng.integers(S // 4, 3 * S // 4)
    t1 = corpus.sample_tokens(DOMAINS[d1], 1, S, rng)[0]
    t2 = corpus.sample_tokens(DOMAINS[d2], 1, S, rng)[0]
    halves.append(np.concatenate([t1[:cut], t2[cut:]]))
    pair_list.append((int(d1), int(d2)))
toks = np.stack(halves)

batches = []
for i in range(0, N, 64):
    b = mlm_batch(toks[i:i + 64], rng, 0.15, corpus.vocab_size)
    b["domain"] = np.full(len(b["tokens"]), -1, np.int32)
    batches.append(b)
q = build_q_table(lib, batches)
masked = np.concatenate([b["tokens"] for b in batches])

pred = np.concatenate([
    np.asarray(jax.jit(lambda t: predict_losses(rp, rc, {"tokens": t}))(
        masked[i:i + 256])) for i in range(0, N, 256)])

choices = {
    "tryage": pred.argmin(1),
    "oracle": bl.oracle_choices(q),
    "random": bl.random_router(N, len(lib), 0),
    "leaderboard": bl.leaderboard_router(art["q_train"], N),
    "keyword (gorilla-class)": bl.keyword_router(masked, corpus, lib),
}
res = {
    "n_prompts": N,
    "selection_accuracy": {k: bl.selection_accuracy(v, q)
                           for k, v in choices.items()},
    "aggregate_accuracy": {k: mlm_accuracy(q, v) for k, v in choices.items()},
}
out = os.path.join(ex.ART_DIR, "mixed_results.json")
with open(out, "w") as f:
    json.dump(res, f, indent=1)
print(json.dumps(res, indent=1))
