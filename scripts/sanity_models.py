"""Quick developer sanity check: reduced variant of every arch runs
train/prefill/decode on CPU without NaNs. Not part of the test suite."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models import (decode_step, init_model,
                          lm_loss, prefill, count_params)

archs = sys.argv[1:] or list_archs()
key = jax.random.PRNGKey(0)
for a in archs:
    cfg = get_config(a).reduced()
    params, logical = init_model(key, cfg)
    B, S = 2, 64
    if cfg.is_encoder or cfg.family in ("vlm", "audio"):
        batch = {
            "embeds": jax.random.normal(key, (B, S, cfg.d_model), jnp.float32),
            "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
            "mask": jnp.ones((B, S), jnp.int32),
        }
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch = {"tokens": toks}
    loss, metrics = jax.jit(lambda p, b: lm_loss(p, cfg, b))(params, batch)
    line = f"{a:18s} params={count_params(params):>10,d} loss={float(loss):8.4f}"
    assert jnp.isfinite(loss), a
    if not cfg.is_encoder:
        pre_batch = dict(batch)
        pre_batch.pop("targets", None), pre_batch.pop("mask", None)
        logits, state = jax.jit(lambda p, b: prefill(p, cfg, b, cache_capacity=S + 1))(params, pre_batch)
        assert jnp.all(jnp.isfinite(logits.astype(jnp.float32))), a
        # decode one token against the prefill state
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        dbatch = {"tokens": tok}
        if cfg.family == "vlm":
            dbatch = {"tokens": tok}
        lg, state = jax.jit(lambda p, b, st: decode_step(p, cfg, b, st, S))(
            params, dbatch, state)
        assert jnp.all(jnp.isfinite(lg.astype(jnp.float32))), a
        line += " decode-ok"
    print(line)
print("ALL OK")
