"""Generate the §Dry-run and §Roofline markdown tables from
experiments/dryrun/*.json.  Usage:

  PYTHONPATH=src python scripts/make_roofline_table.py [--mesh pod]
"""

import argparse
import json
import os

REPO = os.path.join(os.path.dirname(__file__), "..")
DRY = os.path.join(REPO, "experiments", "dryrun")

ARCHS = ["qwen2_vl_72b", "qwen15_05b", "jamba_v01_52b", "grok1_314b",
         "qwen2_moe_a27b", "hubert_xlarge", "tinyllama_11b",
         "starcoder2_15b", "xlstm_13b", "gemma3_4b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh, tag=""):
    recs = {}
    for a in ARCHS:
        for s in SHAPES:
            suffix = f"_{tag}" if tag else ""
            path = os.path.join(DRY, f"{a}_{s}_{mesh}{suffix}.json")
            if os.path.exists(path):
                with open(path) as f:
                    recs[(a, s)] = json.load(f)
    return recs


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def emit(mesh, recs):
    print(f"\n### {mesh} mesh ({'512' if mesh=='multipod' else '256'} chips)\n")
    print("| arch | shape | status | params | peak GiB/dev | t_comp | "
          "t_mem | t_coll | dominant | useful FLOP frac | coll ops |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in SHAPES:
            r = recs.get((a, s))
            if r is None:
                print(f"| {a} | {s} | MISSING | | | | | | | | |")
                continue
            if r["status"] == "SKIP":
                print(f"| {a} | {s} | SKIP | | | | | | | | "
                      f"{r['reason'][:60]} |")
                continue
            if r["status"] == "FAIL":
                print(f"| {a} | {s} | FAIL | | | | | | | | "
                      f"{r['error'][:60]} |")
                continue
            rl = r["roofline"]
            mem = r["memory"]["peak_bytes_per_device"] / 2**30
            uf = r.get("useful_flops_frac")
            coll = r["collectives"]["total_count"]
            print(f"| {a} | {s} | OK | {r['total_params']/1e9:.1f}B | "
                  f"{mem:.2f} | {fmt_s(rl['t_compute_s'])} | "
                  f"{fmt_s(rl['t_memory_s'])} | {fmt_s(rl['t_collective_s'])} | "
                  f"{rl['dominant']} | "
                  f"{uf:.2f} | {coll} |" if uf is not None else "| - |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    meshes = [args.mesh] if args.mesh else ["pod", "multipod"]
    for m in meshes:
        emit(m, load(m, args.tag))


if __name__ == "__main__":
    main()
